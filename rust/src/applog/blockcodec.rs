//! Per-column block codecs for sealed-segment images.
//!
//! Sealed segments persist as self-contained compressed images
//! ([`super::segment::SealedSegment`]): each column block is run through
//! one of three small pure-Rust byte codecs, chosen **per column at seal
//! time** by a cheap size probe ([`encode_block`] with
//! [`CodecPolicy::Probe`]).
//!
//! * [`BlockCodec::Raw`] — stored bytes, zero transform. The floor the
//!   probe never does worse than.
//! * [`BlockCodec::Lz`] — a greedy LZ77-class byte compressor (4-byte
//!   hash-table match finder, varint-coded literal runs and
//!   offset/length matches). Targets the repetitive payload dictionaries
//!   and near-constant delta columns real behavior logs produce.
//! * [`BlockCodec::Rle`] — byte run-length pairs. Wins on long constant
//!   runs (e.g. type-code columns of single-type bursts) and loses
//!   everywhere else, which is why the probe exists.
//!
//! Decompression is fully validating: the caller supplies the expected
//! raw length and every malformed input (overflowing run, out-of-range
//! match offset, trailing bytes) is an error, never a silently wrong
//! block. Both directions are deterministic, so re-encoding the same
//! rows always yields byte-identical images (the persistence round-trip
//! tests rely on this).

use anyhow::{bail, ensure, Result};

use crate::util::wire::{get_u8, get_varint, put_varint, take};

/// Minimum match length the LZ codec encodes (shorter matches cost more
/// than the literals they replace).
const MIN_MATCH: usize = 4;

/// Hash-table size (log2) of the LZ match finder.
const HASH_BITS: u32 = 13;

/// One block compression codec (the tag is what segment images store).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockCodec {
    /// Stored bytes, no transform.
    Raw = 0,
    /// Greedy LZ77-class compressor.
    Lz = 1,
    /// Byte run-length encoding.
    Rle = 2,
}

impl BlockCodec {
    /// Wire tag of this codec.
    #[inline]
    pub fn tag(self) -> u8 {
        self as u8
    }

    /// Codec from its wire tag.
    pub fn from_tag(tag: u8) -> Result<BlockCodec> {
        match tag {
            0 => Ok(BlockCodec::Raw),
            1 => Ok(BlockCodec::Lz),
            2 => Ok(BlockCodec::Rle),
            t => bail!("unknown block codec tag {t}"),
        }
    }
}

/// Codec selection policy, configured per store
/// ([`super::store::StoreConfig::block_codec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CodecPolicy {
    /// Always store raw (the uncompressed baseline arm).
    Raw,
    /// Always LZ, even when it inflates.
    Lz,
    /// Always RLE, even when it inflates.
    Rle,
    /// Probe: compress with every codec, keep the smallest (ties break
    /// toward the cheaper decoder: Raw, then Lz, then Rle).
    #[default]
    Probe,
}

/// Compress `raw` with a fixed codec.
pub fn compress(codec: BlockCodec, raw: &[u8]) -> Vec<u8> {
    match codec {
        BlockCodec::Raw => raw.to_vec(),
        BlockCodec::Lz => lz_compress(raw),
        BlockCodec::Rle => rle_compress(raw),
    }
}

/// Decompress a block, validating against the expected raw length. Any
/// structural damage (bad run, out-of-range offset, overflow, trailing
/// bytes) is rejected.
pub fn decompress(codec: BlockCodec, enc: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    match codec {
        BlockCodec::Raw => {
            ensure!(
                enc.len() == raw_len,
                "raw block is {} bytes, expected {raw_len}",
                enc.len()
            );
            Ok(enc.to_vec())
        }
        BlockCodec::Lz => lz_decompress(enc, raw_len),
        BlockCodec::Rle => rle_decompress(enc, raw_len),
    }
}

/// Encode a block under a policy: fixed policies always use their codec
/// (the ablation arms measure the honest cost); `Probe` keeps the
/// smallest output.
pub fn encode_block(policy: CodecPolicy, raw: &[u8]) -> (BlockCodec, Vec<u8>) {
    match policy {
        CodecPolicy::Raw => (BlockCodec::Raw, raw.to_vec()),
        CodecPolicy::Lz => (BlockCodec::Lz, lz_compress(raw)),
        CodecPolicy::Rle => (BlockCodec::Rle, rle_compress(raw)),
        CodecPolicy::Probe => {
            let mut best = (BlockCodec::Raw, raw.to_vec());
            for codec in [BlockCodec::Lz, BlockCodec::Rle] {
                let enc = compress(codec, raw);
                if enc.len() < best.1.len() {
                    best = (codec, enc);
                }
            }
            best
        }
    }
}

/// 4-byte rolling hash (Knuth multiplicative).
#[inline]
fn hash4(window: &[u8]) -> usize {
    let v = u32::from_le_bytes([window[0], window[1], window[2], window[3]]);
    (v.wrapping_mul(2_654_435_761) >> (32 - HASH_BITS)) as usize
}

/// Greedy LZ77 encode: `( lit_len varint | literals | offset varint |
/// extra_len varint )*` with a trailing literal-only sequence. Match
/// length is `MIN_MATCH + extra_len`; offsets count back from the
/// current output position (`>= 1`, overlapping matches allowed).
fn lz_compress(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() / 2 + 16);
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i + MIN_MATCH <= raw.len() {
        let h = hash4(&raw[i..]);
        let cand = head[h];
        head[h] = i;
        if cand != usize::MAX && raw[cand..cand + MIN_MATCH] == raw[i..i + MIN_MATCH] {
            let mut mlen = MIN_MATCH;
            while i + mlen < raw.len() && raw[cand + mlen] == raw[i + mlen] {
                mlen += 1;
            }
            put_varint(&mut out, (i - lit_start) as u64);
            out.extend_from_slice(&raw[lit_start..i]);
            put_varint(&mut out, (i - cand) as u64);
            put_varint(&mut out, (mlen - MIN_MATCH) as u64);
            i += mlen;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    put_varint(&mut out, (raw.len() - lit_start) as u64);
    out.extend_from_slice(&raw[lit_start..]);
    out
}

/// Validating LZ decode (see [`lz_compress`] for the format).
fn lz_decompress(enc: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut pos = 0usize;
    while out.len() < raw_len {
        let lit = get_varint(enc, &mut pos)?;
        ensure!(
            lit <= (raw_len - out.len()) as u64,
            "lz literal run overflows declared length"
        );
        out.extend_from_slice(take(enc, &mut pos, lit as usize)?);
        if out.len() == raw_len {
            break;
        }
        let off = get_varint(enc, &mut pos)? as usize;
        let extra = get_varint(enc, &mut pos)?;
        ensure!(off >= 1 && off <= out.len(), "lz match offset {off} out of range");
        ensure!(
            extra <= (raw_len - out.len()) as u64
                && MIN_MATCH as u64 + extra <= (raw_len - out.len()) as u64,
            "lz match overflows declared length"
        );
        let mlen = MIN_MATCH + extra as usize;
        let start = out.len() - off;
        // Byte-at-a-time: overlapping matches replicate earlier output.
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
    }
    ensure!(pos == enc.len(), "trailing bytes in lz block");
    Ok(out)
}

/// Run-length encode: `( byte | run varint )*`.
fn rle_compress(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() / 4 + 16);
    let mut i = 0usize;
    while i < raw.len() {
        let b = raw[i];
        let mut run = 1usize;
        while i + run < raw.len() && raw[i + run] == b {
            run += 1;
        }
        out.push(b);
        put_varint(&mut out, run as u64);
        i += run;
    }
    out
}

/// Validating RLE decode.
fn rle_decompress(enc: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut pos = 0usize;
    while pos < enc.len() {
        let b = get_u8(enc, &mut pos)?;
        let run = get_varint(enc, &mut pos)?;
        ensure!(run >= 1, "zero-length rle run");
        ensure!(
            run <= (raw_len - out.len()) as u64,
            "rle run overflows declared length"
        );
        out.extend(std::iter::repeat(b).take(run as usize));
    }
    ensure!(out.len() == raw_len, "rle block shorter than declared length");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SimRng;

    fn corpora() -> Vec<Vec<u8>> {
        let mut rng = SimRng::seed_from_u64(11);
        let mut random = vec![0u8; 700];
        for b in &mut random {
            *b = (rng.next_u64() & 0xFF) as u8;
        }
        let repetitive: Vec<u8> = b"click{\"item\":42,\"pos\":7}"
            .iter()
            .cycle()
            .take(900)
            .copied()
            .collect();
        vec![
            Vec::new(),
            vec![7],
            vec![0u8; 512],          // pure run
            (0..=255u8).collect(),   // incompressible ramp
            random,                  // incompressible noise
            repetitive,              // lz territory
            b"aaaabbbbccccaaaabbbbcccc".to_vec(),
        ]
    }

    #[test]
    fn every_codec_roundtrips_every_corpus() {
        for raw in corpora() {
            for codec in [BlockCodec::Raw, BlockCodec::Lz, BlockCodec::Rle] {
                let enc = compress(codec, &raw);
                let back = decompress(codec, &enc, raw.len()).unwrap();
                assert_eq!(back, raw, "codec {codec:?} len {}", raw.len());
            }
        }
    }

    #[test]
    fn probe_never_exceeds_raw_and_compresses_structured_data() {
        for raw in corpora() {
            let (codec, enc) = encode_block(CodecPolicy::Probe, &raw);
            assert!(enc.len() <= raw.len(), "{codec:?} inflated");
            let back = decompress(codec, &enc, raw.len()).unwrap();
            assert_eq!(back, raw);
        }
        // Structured corpora must actually shrink.
        let (codec, enc) = encode_block(CodecPolicy::Probe, &vec![0u8; 512]);
        assert_eq!(codec, BlockCodec::Rle);
        assert!(enc.len() < 8);
        let repetitive: Vec<u8> = b"abcdefgh".iter().cycle().take(800).copied().collect();
        let (codec, enc) = encode_block(CodecPolicy::Probe, &repetitive);
        assert_eq!(codec, BlockCodec::Lz);
        assert!(enc.len() < repetitive.len() / 4);
    }

    #[test]
    fn fixed_policies_honor_their_codec() {
        let noise: Vec<u8> = (0..=255u8).collect();
        let (c, enc) = encode_block(CodecPolicy::Rle, &noise);
        assert_eq!(c, BlockCodec::Rle);
        assert!(enc.len() > noise.len()); // honest inflation, not a silent fallback
        let (c, _) = encode_block(CodecPolicy::Raw, &noise);
        assert_eq!(c, BlockCodec::Raw);
        let (c, _) = encode_block(CodecPolicy::Lz, &noise);
        assert_eq!(c, BlockCodec::Lz);
    }

    #[test]
    fn compression_is_deterministic() {
        let repetitive: Vec<u8> = b"xyz123".iter().cycle().take(600).copied().collect();
        for codec in [BlockCodec::Lz, BlockCodec::Rle] {
            assert_eq!(compress(codec, &repetitive), compress(codec, &repetitive));
        }
    }

    #[test]
    fn decompress_rejects_malformed_input() {
        // Wrong declared length for raw.
        assert!(decompress(BlockCodec::Raw, b"abc", 4).is_err());
        // RLE run overflowing the declared length.
        let mut enc = Vec::new();
        enc.push(7u8);
        put_varint(&mut enc, 100);
        assert!(decompress(BlockCodec::Rle, &enc, 10).is_err());
        // RLE zero-length run.
        assert!(decompress(BlockCodec::Rle, &[7, 0], 10).is_err());
        // RLE short output.
        assert!(decompress(BlockCodec::Rle, &[7, 3], 10).is_err());
        // LZ out-of-range match offset.
        let mut enc = Vec::new();
        put_varint(&mut enc, 1); // 1 literal
        enc.push(b'a');
        put_varint(&mut enc, 9); // offset past output
        put_varint(&mut enc, 0);
        assert!(decompress(BlockCodec::Lz, &enc, 8).is_err());
        // LZ literal run past the declared length.
        let mut enc = Vec::new();
        put_varint(&mut enc, 50);
        enc.extend_from_slice(&[0u8; 50]);
        assert!(decompress(BlockCodec::Lz, &enc, 10).is_err());
        // LZ trailing bytes after the output is complete.
        let valid = compress(BlockCodec::Lz, b"hello");
        let mut long = valid.clone();
        long.push(0);
        assert!(decompress(BlockCodec::Lz, &long, 5).is_err());
        assert_eq!(decompress(BlockCodec::Lz, &valid, 5).unwrap(), b"hello");
        // Truncation of every codec's output is rejected.
        let src: Vec<u8> = b"aabbccdd".iter().cycle().take(300).copied().collect();
        for codec in [BlockCodec::Lz, BlockCodec::Rle] {
            let enc = compress(codec, &src);
            assert!(decompress(codec, &enc[..enc.len() - 1], src.len()).is_err());
        }
        // Unknown tag.
        assert!(BlockCodec::from_tag(9).is_err());
        for codec in [BlockCodec::Raw, BlockCodec::Lz, BlockCodec::Rle] {
            assert_eq!(BlockCodec::from_tag(codec.tag()).unwrap(), codec);
        }
    }

    #[test]
    fn overlapping_matches_decode_correctly() {
        // "abc" then a self-overlapping run of "abcabcabc..." exercises
        // the byte-at-a-time match copy.
        let raw: Vec<u8> = b"abc".iter().cycle().take(100).copied().collect();
        let enc = compress(BlockCodec::Lz, &raw);
        assert!(enc.len() < 20, "period-3 run should collapse, got {}", enc.len());
        assert_eq!(decompress(BlockCodec::Lz, &enc, raw.len()).unwrap(), raw);
    }
}
