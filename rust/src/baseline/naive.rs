//! The *w/o AutoFeature* baseline: independent per-feature extraction.

use std::time::Instant;

use anyhow::Result;

use crate::applog::codec::{AttrCodec, CodecKind};
use crate::applog::store::AppLogStore;
use crate::engine::exec::pipeline::run_standalone;
use crate::engine::online::ExtractionResult;
use crate::engine::Extractor;
use crate::features::spec::FeatureSpec;
use crate::fegraph::graph::FeGraph;
use crate::optimizer::lower::{lower, ExecPlan, LowerConfig};
use crate::optimizer::plan::OptimizedPlan;

/// Industry-standard on-device feature extraction: each user feature is
/// extracted independently without optimization (paper §4.1 baselines).
/// Executes through the same lowered-pipeline executor as the engine
/// (the baseline's chain-per-feature shape is lowered once, here).
pub struct NaiveExtractor {
    graph: FeGraph,
    opt: OptimizedPlan,
    exec: ExecPlan,
    codec: Box<dyn AttrCodec>,
}

impl NaiveExtractor {
    /// Build the unoptimized FE-graph for a feature set and lower it to
    /// its one-shot ExecPlan (one single-member pipeline per sub-chain,
    /// full decode — the unoptimized cost shape).
    pub fn new(features: Vec<FeatureSpec>, codec: CodecKind) -> Self {
        let graph = FeGraph::from_specs(features);
        let opt = crate::optimizer::fusion::fuse(&graph.features, false);
        let exec = lower(&opt, &LowerConfig::baseline());
        NaiveExtractor {
            graph,
            opt,
            exec,
            codec: codec.build(),
        }
    }

    /// The underlying graph (inspection).
    pub fn graph(&self) -> &FeGraph {
        &self.graph
    }
}

impl Extractor for NaiveExtractor {
    fn extract(&mut self, store: &AppLogStore, now: i64) -> Result<ExtractionResult> {
        let wall = Instant::now();
        let out = run_standalone(&self.opt, &self.exec, self.codec.as_ref(), store, now)?;
        let (values, breakdown) = (out.values, out.counters.breakdown());
        Ok(ExtractionResult {
            values,
            breakdown,
            wall_ns: wall.elapsed().as_nanos() as u64,
            cache_bytes: 0,
            cached_types: 0,
            boundary_cmps: 0,
            served_stale: false,
            extra_storage_bytes: 0,
            replan: None,
        })
    }

    fn label(&self) -> &'static str {
        "w/o AutoFeature"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::codec::JsonishCodec;
    use crate::applog::event::AttrValue;
    use crate::applog::store::StoreConfig;
    use crate::features::compute::CompFunc;
    use crate::features::spec::{FeatureId, TimeRange};
    use crate::features::value::FeatureValue;

    #[test]
    fn repeats_work_per_feature() {
        let codec = JsonishCodec;
        let mut store = AppLogStore::new(StoreConfig::default());
        for i in 0..20i64 {
            store
                .append(0, i * 1000, codec.encode(&[(0, AttrValue::Int(i))]))
                .unwrap();
        }
        let specs: Vec<_> = (0..5)
            .map(|i| {
                FeatureSpec {
                    id: FeatureId(i),
                    name: format!("f{i}"),
                    event_types: vec![0],
                    window: TimeRange::secs(20),
                    attrs: vec![0],
                    comp: CompFunc::Count,
                }
                .normalized()
            })
            .collect();
        let mut n = NaiveExtractor::new(specs, CodecKind::Jsonish);
        let r = n.extract(&store, 20_000).unwrap();
        assert_eq!(r.values, vec![FeatureValue::Scalar(20.0); 5]);
        // The defining inefficiency: 5 features x 20 rows all re-decoded.
        assert_eq!(r.breakdown.rows_decoded, 100);
        assert_eq!(n.label(), "w/o AutoFeature");
    }
}
