//! Latency metrics for the service coordinator.

use crate::fegraph::node::OpBreakdown;

/// Online latency recorder (extraction / inference / end-to-end).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    extraction_ns: Vec<u64>,
    inference_ns: Vec<u64>,
    breakdown: OpBreakdown,
}

impl LatencyRecorder {
    /// Create an empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request.
    pub fn record(&mut self, extraction_ns: u64, inference_ns: u64, bd: &OpBreakdown) {
        self.extraction_ns.push(extraction_ns);
        self.inference_ns.push(inference_ns);
        self.breakdown.merge(bd);
    }

    /// Number of recorded requests.
    pub fn len(&self) -> usize {
        self.extraction_ns.len()
    }

    /// Whether nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.extraction_ns.is_empty()
    }

    /// Mean end-to-end latency (ms).
    pub fn mean_ms(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.extraction_ns
            .iter()
            .zip(&self.inference_ns)
            .map(|(e, i)| (e + i) as f64)
            .sum::<f64>()
            / self.len() as f64
            / 1e6
    }

    /// End-to-end latency percentile (ms).
    pub fn percentile_ms(&self, q: f64) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let mut v: Vec<u64> = self
            .extraction_ns
            .iter()
            .zip(&self.inference_ns)
            .map(|(e, i)| e + i)
            .collect();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * q).round() as usize;
        v[idx] as f64 / 1e6
    }

    /// Share of total time spent in feature extraction (the Fig. 4
    /// bottleneck statistic).
    pub fn extraction_share(&self) -> f64 {
        let e: u64 = self.extraction_ns.iter().sum();
        let i: u64 = self.inference_ns.iter().sum();
        if e + i == 0 {
            0.0
        } else {
            e as f64 / (e + i) as f64
        }
    }

    /// Accumulated per-op breakdown.
    pub fn breakdown(&self) -> &OpBreakdown {
        &self.breakdown
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_over_known_samples() {
        let mut rec = LatencyRecorder::new();
        for e in [1_000_000u64, 2_000_000, 3_000_000] {
            rec.record(e, 1_000_000, &OpBreakdown::default());
        }
        assert_eq!(rec.len(), 3);
        assert!((rec.mean_ms() - 3.0).abs() < 1e-9);
        assert!((rec.percentile_ms(0.5) - 3.0).abs() < 1e-9);
        assert!((rec.extraction_share() - 6.0 / 9.0).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let rec = LatencyRecorder::new();
        assert_eq!(rec.mean_ms(), 0.0);
        assert_eq!(rec.percentile_ms(0.9), 0.0);
        assert_eq!(rec.extraction_share(), 0.0);
    }
}
