//! Storage accounting for the cloud-side baselines (Table 1, Fig. 18b).
//!
//! SQLite-style cost model:
//! * raw app log row — header columns + the compressed attr blob
//!   ([`crate::applog::event::BehaviorEvent::storage_bytes`]);
//! * wide-column decoded row — header + each present attribute stored
//!   decoded + a null bitmap over the table's *global* column set (one
//!   column per unique attribute across all behavior types — the
//!   "massive columns" of Table 1).

use crate::applog::event::{AttrId, AttrValue};
use crate::applog::schema::Catalog;

/// Decoded in-storage size of one attribute value (SQLite serial-type
/// style: 8-byte numerics, length-prefixed text).
pub fn decoded_value_bytes(v: &AttrValue) -> usize {
    match v {
        AttrValue::Int(_) | AttrValue::Float(_) => 8,
        AttrValue::Str(s) => s.len() + 2,
    }
}

/// Total unique attribute columns across all behavior types: attributes
/// of different behavior types are distinct columns (heterogeneous
/// schemas — paper footnote 1).
pub fn global_column_count(catalog: &Catalog) -> usize {
    catalog.schemas.iter().map(|s| s.attrs.len()).sum()
}

/// Bytes of one wide-column decoded row: header + present values +
/// null bitmap over the global column set.
pub fn wide_row_bytes(present: &[(AttrId, AttrValue)], global_columns: usize) -> usize {
    let header = 18; // seq, type, timestamp — as in the raw log
    let values: usize = present.iter().map(|(_, v)| 2 + decoded_value_bytes(v)).sum();
    let null_bitmap = global_columns.div_ceil(8);
    header + values + null_bitmap
}

/// Bytes of one per-feature pre-filtered row (Feature Store): header +
/// only the feature's needed attrs + the same global null bitmap
/// (Table 1 lists Feature Store's structure as redundant rows *and*
/// massive columns).
pub fn feature_row_bytes(needed: &[(AttrId, AttrValue)], global_columns: usize) -> usize {
    wide_row_bytes(needed, global_columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::schema::CatalogConfig;

    #[test]
    fn global_columns_sum_schema_sizes() {
        let cat = Catalog::generate(&CatalogConfig::small(), 1);
        let want: usize = cat.schemas.iter().map(|s| s.attrs.len()).sum();
        assert_eq!(global_column_count(&cat), want);
    }

    #[test]
    fn wide_row_charges_null_bitmap() {
        let present = vec![(0u16, AttrValue::Int(5))];
        let narrow = wide_row_bytes(&present, 8);
        let wide = wide_row_bytes(&present, 4000);
        assert_eq!(wide - narrow, 4000 / 8 - 1);
    }

    #[test]
    fn string_values_cost_their_length() {
        let a = wide_row_bytes(&[(0, AttrValue::Str("x".into()))], 8);
        let b = wide_row_bytes(&[(0, AttrValue::Str("xxxxxxxxxx".into()))], 8);
        assert_eq!(b - a, 9);
    }
}
