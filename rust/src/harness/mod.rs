//! Experiment harness: shared machinery regenerating every table and
//! figure of the paper's evaluation (§4). Used by `rust/benches/*` and
//! `examples/*`; see DESIGN.md §3 for the experiment index.

pub mod experiments;

use std::path::Path;

use anyhow::Result;

use crate::applog::codec::CodecKind;
use crate::applog::schema::{Catalog, CatalogConfig};
use crate::baseline::decoded_log::DecodedLogExtractor;
use crate::baseline::feature_store::FeatureStoreExtractor;
use crate::baseline::naive::NaiveExtractor;
use crate::baseline::storage::global_column_count;
use crate::cache::policy::PolicyKind;
use crate::engine::config::EngineConfig;
use crate::engine::online::Engine;
use crate::engine::Extractor;
use crate::features::spec::FeatureSpec;
use crate::runtime::ModelRuntime;
use crate::workload::driver::{run_simulation, SimConfig, SimOutcome};
use crate::workload::services::{ServiceKind, ServiceSpec};

/// Catalog seed shared by every experiment (deterministic workloads).
pub const CATALOG_SEED: u64 = 42;

/// Build the evaluation catalog (Fig. 3-shaped, 40 behavior types).
pub fn eval_catalog() -> Catalog {
    Catalog::generate(&CatalogConfig::paper(), CATALOG_SEED)
}

/// Every extraction method compared in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Industry-standard independent per-feature extraction.
    Naive,
    /// Graph optimizer only (*w/ Fusion*).
    FusionOnly,
    /// Cache policy only (*w/ Cache*).
    CacheOnly,
    /// Full AutoFeature.
    AutoFeature,
    /// AutoFeature plus persistent incremental compute (O(Δ)
    /// Filter+Compute per trigger; the PR 4 tentpole's ablation arm).
    Incremental,
    /// AutoFeature with the random cache policy (*w/ Random*, Fig. 19b).
    RandomCache,
    /// Cloud baseline 1 (Table 1).
    DecodedLog,
    /// Cloud baseline 2 (Table 1).
    FeatureStore,
}

impl Method {
    /// The four methods of the headline comparison (Fig. 16).
    pub const FIG16: [Method; 4] = [
        Method::Naive,
        Method::FusionOnly,
        Method::CacheOnly,
        Method::AutoFeature,
    ];

    /// Display label (matches the paper's legends).
    pub fn label(&self) -> &'static str {
        match self {
            Method::Naive => "w/o AutoFeature",
            Method::FusionOnly => "w/ Fusion",
            Method::CacheOnly => "w/ Cache",
            Method::AutoFeature => "AutoFeature",
            Method::Incremental => "AutoFeature+Δ",
            Method::RandomCache => "w/ Random",
            Method::DecodedLog => "Decoded Log",
            Method::FeatureStore => "Feature Store",
        }
    }
}

/// Instantiate an extractor for a method over a feature set.
pub fn make_extractor(
    method: Method,
    features: Vec<FeatureSpec>,
    catalog: &Catalog,
    cache_budget: usize,
) -> Result<Box<dyn Extractor>> {
    let engine_cfg = |mut cfg: EngineConfig| {
        cfg.cache_budget_bytes = cache_budget;
        cfg
    };
    Ok(match method {
        Method::Naive => Box::new(NaiveExtractor::new(features, CodecKind::Jsonish)),
        Method::FusionOnly => Box::new(Engine::new(
            features,
            catalog,
            engine_cfg(EngineConfig::fusion_only()),
        )?),
        Method::CacheOnly => Box::new(Engine::new(
            features,
            catalog,
            engine_cfg(EngineConfig::cache_only()),
        )?),
        Method::AutoFeature => Box::new(Engine::new(
            features,
            catalog,
            engine_cfg(EngineConfig::autofeature()),
        )?),
        Method::Incremental => Box::new(Engine::new(
            features,
            catalog,
            engine_cfg(EngineConfig::incremental()),
        )?),
        Method::RandomCache => Box::new(Engine::new(
            features,
            catalog,
            engine_cfg(EngineConfig {
                policy: PolicyKind::Random(0xBAD5EED),
                ..EngineConfig::autofeature()
            }),
        )?),
        Method::DecodedLog => Box::new(DecodedLogExtractor::new(
            features,
            CodecKind::Jsonish,
            global_column_count(catalog),
        )),
        Method::FeatureStore => Box::new(FeatureStoreExtractor::new(
            features,
            CodecKind::Jsonish,
            global_column_count(catalog),
        )),
    })
}

/// Run one (service, method, sim) cell, optionally with model inference.
pub fn run_cell(
    catalog: &Catalog,
    service: &ServiceSpec,
    method: Method,
    model: Option<&ModelRuntime>,
    sim: &SimConfig,
) -> Result<SimOutcome> {
    let mut extractor = make_extractor(method, service.features.clone(), catalog, 256 * 1024)?;
    let backend = model.map(|m| m as &dyn crate::runtime::InferenceBackend);
    run_simulation(catalog, extractor.as_mut(), backend, sim)
}

/// Run a multi-user fleet of one service through a [`SessionPool`]:
/// compile the plan once, fan the base workload out to `num_users`
/// seeded sessions and shard them across `num_shards` workers under a
/// host-wide cache cap.
pub fn run_fleet(
    catalog: &Catalog,
    service: &ServiceSpec,
    base_sim: &SimConfig,
    num_users: usize,
    num_shards: usize,
    global_cache_cap_bytes: usize,
    model: Option<&(dyn crate::runtime::InferenceBackend + Sync)>,
) -> Result<crate::coordinator::pool::PoolReport> {
    use crate::coordinator::pool::{PoolConfig, SessionConfig, SessionPool};
    let pool = SessionPool::new(
        service.features.clone(),
        catalog,
        PoolConfig {
            num_shards,
            global_cache_cap_bytes,
            ..PoolConfig::default()
        },
    )?;
    let users = SessionConfig::fleet(base_sim, num_users);
    pool.run(catalog, &users, model)
}

/// Run a multi-user fleet of one service through the event-driven
/// [`crate::coordinator::sched::FleetScheduler`]: same fan-out as
/// [`run_fleet`], but sessions multiplex onto `workers` threads via the
/// trigger queue and hibernate per `live_cap_bytes` /
/// `hibernate_after_ms` (see [`crate::coordinator::sched::SchedConfig`]).
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_sched(
    catalog: &Catalog,
    service: &ServiceSpec,
    base_sim: &SimConfig,
    num_users: usize,
    workers: usize,
    global_cache_cap_bytes: usize,
    live_cap_bytes: usize,
    hibernate_after_ms: i64,
    model: Option<&(dyn crate::runtime::InferenceBackend + Sync)>,
) -> Result<crate::coordinator::sched::SchedReport> {
    use crate::coordinator::sched::SchedConfig;
    run_fleet_sched_cfg(
        catalog,
        service,
        base_sim,
        num_users,
        SchedConfig {
            workers,
            global_cache_cap_bytes,
            live_cap_bytes,
            hibernate_after_ms,
            ..SchedConfig::default()
        },
        model,
    )
}

/// Run a fleet through the scheduler with a caller-built
/// [`crate::coordinator::sched::SchedConfig`] — the shared-arena /
/// fused-decode arms of the fleet-dedup experiment need knobs the
/// positional [`run_fleet_sched`] signature doesn't carry.
pub fn run_fleet_sched_cfg(
    catalog: &Catalog,
    service: &ServiceSpec,
    base_sim: &SimConfig,
    num_users: usize,
    cfg: crate::coordinator::sched::SchedConfig,
    model: Option<&(dyn crate::runtime::InferenceBackend + Sync)>,
) -> Result<crate::coordinator::sched::SchedReport> {
    use crate::coordinator::pool::SessionConfig;
    use crate::coordinator::sched::FleetScheduler;
    let sched = FleetScheduler::new(service.features.clone(), catalog, cfg)?;
    let users = SessionConfig::fleet(base_sim, num_users);
    sched.run(catalog, &users, model)
}

/// Load a service's model runtime if its artifact exists.
pub fn try_load_model(artifact_dir: &Path, service: ServiceKind) -> Option<ModelRuntime> {
    if artifact_dir
        .join(format!("model_{}.hlo.txt", service.id()))
        .exists()
    {
        match ModelRuntime::load(artifact_dir, service) {
            Ok(rt) => Some(rt),
            Err(e) => {
                // Distinguish "artifact present but unloadable" (e.g. a
                // default build without the `pjrt` feature) from the
                // plain missing-artifact case callers report themselves.
                eprintln!(
                    "note: artifact for {} exists but could not be loaded: {e:#}",
                    service.id()
                );
                None
            }
        }
    } else {
        None
    }
}

/// Default artifact directory (workspace `artifacts/`).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Pretty-print a table with aligned columns.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:width$}", c, width = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extractor_factory_covers_all_methods() {
        let cat = eval_catalog();
        let svc = ServiceSpec::build(ServiceKind::SR, &cat);
        for m in [
            Method::Naive,
            Method::FusionOnly,
            Method::CacheOnly,
            Method::AutoFeature,
            Method::Incremental,
            Method::RandomCache,
            Method::DecodedLog,
            Method::FeatureStore,
        ] {
            let e = make_extractor(m, svc.features.clone(), &cat, 64 * 1024).unwrap();
            assert!(!e.label().is_empty());
        }
    }

    #[test]
    fn labels_match_paper_legends() {
        assert_eq!(Method::Naive.label(), "w/o AutoFeature");
        assert_eq!(Method::AutoFeature.label(), "AutoFeature");
    }
}
