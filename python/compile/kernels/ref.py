"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

These are the ground truth that `fm_kernel.py` and `seq_attention.py` are
validated against (pytest + hypothesis in ``python/tests/``). They are also
used directly by the model when ``use_ref=True``, which gives an
end-to-end kernel-vs-ref equivalence check at the model level.
"""

from __future__ import annotations

import jax.numpy as jnp


def fm_interaction_ref(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Factorization-machine second-order interaction vector.

    Standard FM identity (Rendle 2010): for each latent dim d,

        out_d = 0.5 * ((sum_i v_id x_i)^2 - sum_i v_id^2 x_i^2)

    Args:
      x: ``[B, n]`` feature values.
      v: ``[n, d]`` latent factor matrix.

    Returns:
      ``[B, d]`` interaction vector.
    """
    s = x @ v  # [B, d]
    q = (x * x) @ (v * v)  # [B, d]
    return 0.5 * (s * s - q)


def attention_pool_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Masked single-head attention pooling.

    Args:
      q: ``[B, d]`` query (one per sequence).
      k: ``[B, L, d]`` keys.
      v: ``[B, L, d]`` values.
      mask: ``[B, L]`` 1.0 for valid positions, 0.0 for padding.

    Returns:
      ``[B, d]`` pooled vector: softmax(q.k/sqrt(d), masked) @ v.
    """
    d = q.shape[-1]
    logits = jnp.einsum("bd,bld->bl", q, k) / jnp.sqrt(jnp.float32(d))
    logits = jnp.where(mask > 0, logits, jnp.float32(-1e30))
    # Numerically stable softmax; fully-masked rows yield a zero vector.
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * (mask > 0)
    z = jnp.sum(e, axis=-1, keepdims=True)
    w = e / jnp.maximum(z, 1e-30)
    return jnp.einsum("bl,bld->bd", w, v)
