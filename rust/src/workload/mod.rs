//! Workload substrate: behavior catalogs, synthetic user traces and the
//! five evaluated mobile services.
//!
//! The paper evaluates on 10 real users' traces across noon / evening /
//! night periods (§4.1, Appendix A). We reproduce the *published
//! statistics* of those traces — per-type frequencies per 10-minute
//! segment, activity percentiles (P30 < 5 behaviors/10 min, P90 > 45),
//! and the longer uninterrupted night sessions §4.2 uses to explain the
//! higher night-time speedups — with a seeded generator
//! ([`traces::TraceGenerator`]). See DESIGN.md §Substitutions.

pub mod behavior;
pub mod driver;
pub mod services;
pub mod traces;
