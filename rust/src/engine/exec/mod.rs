//! Execution of lowered [`ExecPlan`](crate::optimizer::lower::ExecPlan)
//! pipelines — the online phase's engine room.
//!
//! `engine/online.rs` used to hand-thread three execution strategies
//! (classic rewalk, incremental delta, uncached one-shot) through ad-hoc
//! row vectors; this module family replaces all of that with **one
//! executor over the explicit IR**:
//!
//! * [`pipeline`] — the executor: strategy dispatch, lane walks, the
//!   per-operator rows-in/rows-out/ns counter table that produces the
//!   extraction's `OpBreakdown`.
//! * [`batch`] — the batch-grain walkers (`ExecMode::Batch`): the
//!   uncached one-shot path over `ColumnBatch + SelectionVector`
//!   (zero row materialization) and the sliced cached-rewalk.
//! * [`materialize`] — the row/cache bridge: cache fetch + missing-
//!   interval scan into per-type row sets, and the budgeted cache
//!   update. The only place rows become `CachedRow`s.
//! * [`delta`] — the `WindowSlice`/`Aggregate` stages of the
//!   incremental strategy: persistent state banks
//!   (`features::incremental`) fed boundary-sliced deltas, with the
//!   exact-recompute repair fallback.
//!
//! The unoptimized `fegraph::exec` baseline re-targets
//! [`pipeline::run_standalone`], so there is exactly one extraction
//! semantics in the crate.

pub(crate) mod batch;
pub(crate) mod delta;
pub(crate) mod materialize;
pub mod pipeline;
#[cfg(test)]
pub(crate) mod testutil;
