//! Shared fixture for the engine/executor test suites — one canonical
//! mixed-window scenario so the online, pipeline, materialize and
//! delta suites all exercise exactly the same workload (and a tweak to
//! it lands everywhere at once).

use crate::applog::codec::JsonishCodec;
use crate::applog::schema::{Catalog, CatalogConfig};
use crate::applog::store::{AppLogStore, StoreConfig};
use crate::features::catalog::{generate_feature_set, FeatureSetConfig};
use crate::features::spec::{FeatureSpec, TimeRange};
use crate::workload::traces::{log_events, TraceConfig, TraceGenerator};

/// 30 features over 8 types (70% identical conditions, 5 min / 30 min /
/// 1 h windows, 30% multi-type) plus 45 minutes of seeded trace.
pub(crate) fn setup() -> (Catalog, Vec<FeatureSpec>, AppLogStore) {
    let cat = Catalog::generate(&CatalogConfig::paper(), 42);
    let specs = generate_feature_set(
        &cat,
        &FeatureSetConfig {
            num_features: 30,
            num_types: 8,
            identical_share: 0.7,
            windows: vec![
                TimeRange::mins(5),
                TimeRange::mins(30),
                TimeRange::hours(1),
            ],
            multi_type_prob: 0.3,
            seed: 77,
        },
    );
    let gen = TraceGenerator::new(&cat);
    let events = gen.generate(&TraceConfig {
        duration_ms: 45 * 60_000,
        seed: 9,
        ..TraceConfig::default()
    });
    let mut store = AppLogStore::new(StoreConfig::default());
    log_events(&mut store, &JsonishCodec, &events).unwrap();
    (cat, specs, store)
}
