//! Cross-module integration tests: the engine configurations, baselines
//! and experiment harness composed over realistic service workloads.

use autofeature::applog::codec::CodecKind;
use autofeature::engine::Extractor;
use autofeature::harness::{self, experiments, Method};
use autofeature::workload::behavior::{ActivityLevel, Period};
use autofeature::workload::driver::{run_simulation, SimConfig};
use autofeature::workload::services::{ServiceKind, ServiceSpec};

fn quick_sim(interval_ms: i64, seed: u64) -> SimConfig {
    SimConfig {
        period: Period::Night,
        activity: ActivityLevel::P70,
        warmup_ms: 25 * 60_000,
        duration_ms: 3 * 60_000,
        inference_interval_ms: interval_ms,
        seed,
        codec: CodecKind::Jsonish,
        ..SimConfig::default()
    }
}

/// Every method must produce identical feature values at every request
/// of a shared workload — the paper's "without compromising accuracy"
/// claim, end-to-end.
#[test]
fn all_methods_agree_on_every_service() {
    let catalog = harness::eval_catalog();
    for kind in [ServiceKind::SR, ServiceKind::CP] {
        let svc = ServiceSpec::build(kind, &catalog);
        let sim = quick_sim(20_000, 9);
        let reference = harness::run_cell(&catalog, &svc, Method::Naive, None, &sim).unwrap();
        for method in [
            Method::FusionOnly,
            Method::CacheOnly,
            Method::AutoFeature,
            Method::RandomCache,
            Method::DecodedLog,
            Method::FeatureStore,
        ] {
            let out = harness::run_cell(&catalog, &svc, method, None, &sim).unwrap();
            assert_eq!(out.records.len(), reference.records.len());
            for (step, (a, b)) in out.records.iter().zip(&reference.records).enumerate() {
                assert_eq!(a.now, b.now);
                for (i, (x, y)) in a
                    .extraction
                    .values
                    .iter()
                    .zip(&b.extraction.values)
                    .enumerate()
                {
                    assert!(
                        x.approx_eq(y, 1e-9),
                        "{kind:?}/{method:?} step {step} feature {i}: {x:?} vs {y:?}"
                    );
                }
            }
        }
    }
}

/// AutoFeature must do strictly less Retrieve/Decode work than naive.
#[test]
fn autofeature_eliminates_redundant_work() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::VR, &catalog);
    let sim = quick_sim(5_000, 4);
    let naive = harness::run_cell(&catalog, &svc, Method::Naive, None, &sim).unwrap();
    let auto = harness::run_cell(&catalog, &svc, Method::AutoFeature, None, &sim).unwrap();
    let decoded = |o: &autofeature::workload::driver::SimOutcome| -> u64 {
        o.records
            .iter()
            .map(|r| r.extraction.breakdown.rows_decoded)
            .sum()
    };
    assert!(
        decoded(&auto) * 4 < decoded(&naive),
        "auto {} vs naive {}",
        decoded(&auto),
        decoded(&naive)
    );
    // And be faster end-to-end on extraction.
    assert!(auto.mean_extraction_ms() < naive.mean_extraction_ms());
}

/// The ablations must sit between naive and full AutoFeature in work
/// performed (each removes one redundancy source).
#[test]
fn ablations_remove_their_redundancy_source() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::CP, &catalog);
    let sim = quick_sim(5_000, 6);
    let naive = harness::run_cell(&catalog, &svc, Method::Naive, None, &sim).unwrap();
    let fusion = harness::run_cell(&catalog, &svc, Method::FusionOnly, None, &sim).unwrap();
    let cache = harness::run_cell(&catalog, &svc, Method::CacheOnly, None, &sim).unwrap();
    let total_decoded = |o: &autofeature::workload::driver::SimOutcome| -> u64 {
        o.records
            .iter()
            .map(|r| r.extraction.breakdown.rows_decoded)
            .sum()
    };
    // Fusion: one decode per (type,row) instead of per (feature,row).
    assert!(total_decoded(&fusion) < total_decoded(&naive));
    // Cache: steady-state decodes only the new rows per request.
    assert!(total_decoded(&cache) < total_decoded(&naive));
    // Cache hits must actually occur after the first request.
    let hits: u64 = cache
        .records
        .iter()
        .skip(1)
        .map(|r| r.extraction.breakdown.rows_from_cache)
        .sum();
    assert!(hits > 0);
}

/// Cloud baselines trade storage for latency (Table 1 / Fig. 18 shape).
/// VR is the service whose feature set covers the most behavior types,
/// which is where the paper's FS > DL ordering holds (the feature store
/// only persists rows some feature needs; DL mirrors every row).
#[test]
fn cloud_baselines_inflate_storage() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::VR, &catalog);
    let sim = quick_sim(8_000, 12);
    let dl = harness::run_cell(&catalog, &svc, Method::DecodedLog, None, &sim).unwrap();
    let fs = harness::run_cell(&catalog, &svc, Method::FeatureStore, None, &sim).unwrap();
    let dl_factor =
        (dl.raw_storage_bytes + dl.extra_storage_bytes) as f64 / dl.raw_storage_bytes as f64;
    let fs_factor =
        (fs.raw_storage_bytes + fs.extra_storage_bytes) as f64 / fs.raw_storage_bytes as f64;
    // Paper: 2.61x and 2.80x; require the qualitative shape.
    assert!(dl_factor > 1.5, "decoded log factor {dl_factor}");
    assert!(fs_factor > dl_factor, "fs {fs_factor} <= dl {dl_factor}");
    // And their online extraction skips Decode entirely.
    for r in &dl.records {
        assert_eq!(r.extraction.breakdown.rows_decoded, 0);
    }
}

/// Periods drive event volume: night > noon (the §4.2 mechanism).
#[test]
fn night_traces_log_more_events() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::SR, &catalog);
    let night = harness::run_cell(
        &catalog,
        &svc,
        Method::Naive,
        None,
        &SimConfig {
            period: Period::Night,
            ..quick_sim(30_000, 3)
        },
    )
    .unwrap();
    let noon = harness::run_cell(
        &catalog,
        &svc,
        Method::Naive,
        None,
        &SimConfig {
            period: Period::Noon,
            ..quick_sim(30_000, 3)
        },
    )
    .unwrap();
    assert!(night.events_logged > noon.events_logged);
}

/// Quick-scale smoke of every experiment driver that doesn't need
/// artifacts (the figure benches run them at full scale).
#[test]
fn experiment_drivers_run_at_quick_scale() {
    let no_models = |_k: ServiceKind| None;
    experiments::fig04_breakdown(experiments::Scale::Quick, &no_models).unwrap();
    let rows = experiments::fig10_op_latency(experiments::Scale::Quick).unwrap();
    assert_eq!(rows.len(), 4);
    let rows = experiments::fig17_overheads(experiments::Scale::Quick).unwrap();
    assert_eq!(rows.len(), 5);
    for row in &rows {
        // Offline optimization stays millisecond-scale (Fig. 17a).
        assert!(row.get("offline_total_ms").unwrap() < 200.0, "{row:?}");
        // Online cache footprint stays under a few hundred KB (Fig. 17b).
        assert!(row.get("peak_cache_kb").unwrap() < 512.0, "{row:?}");
    }
}

/// Fig. 20 shape: speedup decays as the inference interval grows but
/// stays >= 1 at the longest interval.
#[test]
fn interval_sweep_shape() {
    let rows = experiments::fig20_interval(experiments::Scale::Quick).unwrap();
    assert!(rows.len() >= 2);
    for kind in ServiceKind::ALL {
        let key = format!("{}_speedup", kind.id());
        let first = rows.first().unwrap().get(&key).unwrap();
        let last = rows.last().unwrap().get(&key).unwrap();
        assert!(first > 1.0, "{kind:?} fastest-interval speedup {first}");
        assert!(last > 0.8, "{kind:?} slowest-interval speedup {last}");
    }
}

/// Fig. 21 shape: speedup grows with redundancy, amplified at high
/// inference frequency.
#[test]
fn redundancy_sweep_shape() {
    let rows = experiments::fig21_redundancy(experiments::Scale::Quick).unwrap();
    let key = &rows[0].cols[0].0.clone(); // 10s interval column
    let lo = rows.first().unwrap().get(key).unwrap();
    let hi = rows.last().unwrap().get(key).unwrap();
    assert!(hi > lo, "speedup must grow with redundancy: {lo} -> {hi}");
    assert!(hi > 2.0, "90% redundancy at 10s interval: {hi}");
}

/// run_simulation must be deterministic given a seed.
#[test]
fn simulation_is_deterministic() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::KP, &catalog);
    let sim = quick_sim(15_000, 42);
    let mut a = harness::make_extractor(Method::Naive, svc.features.clone(), &catalog, 1024).unwrap();
    let mut b = harness::make_extractor(Method::Naive, svc.features.clone(), &catalog, 1024).unwrap();
    let ra = run_simulation(&catalog, a.as_mut(), None, &sim).unwrap();
    let rb = run_simulation(&catalog, b.as_mut(), None, &sim).unwrap();
    assert_eq!(ra.events_logged, rb.events_logged);
    for (x, y) in ra.records.iter().zip(&rb.records) {
        assert_eq!(x.extraction.values, y.extraction.values);
    }
}

/// Extractor::reset starts a cold period (paper: app exit frees memory).
#[test]
fn reset_restarts_cold() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::SR, &catalog);
    let mut ex =
        harness::make_extractor(Method::AutoFeature, svc.features.clone(), &catalog, 256 * 1024)
            .unwrap();
    let sim = quick_sim(20_000, 5);
    let first = run_simulation(&catalog, ex.as_mut(), None, &sim).unwrap();
    assert!(first
        .records
        .iter()
        .skip(1)
        .any(|r| r.extraction.breakdown.rows_from_cache > 0));
    ex.reset();
    // After reset the next run's first request must be cache-cold.
    let second = run_simulation(&catalog, ex.as_mut(), None, &sim).unwrap();
    assert_eq!(
        second.records[0].extraction.breakdown.rows_from_cache, 0,
        "reset did not clear the cache"
    );
}
