//! Small in-tree utilities.
//!
//! The build image vendors only the `xla` crate closure, so the
//! deterministic PRNG every workload generator needs lives here instead
//! of `rand` (see DESIGN.md §Substitutions).

pub mod rng;
pub mod stats;
pub mod wire;
