//! Session-state serialization for hibernation.
//!
//! [`super::online::Engine::export_state`] flattens everything
//! session-private and mutable — the cached lanes (rows + watermarks),
//! the persistent [`IncrementalState`] bank, and the §5 staleness
//! fast-path clock — into one versioned, CRC-checked blob; `import_state`
//! rebuilds it into a fresh engine over the same shared compiled plan.
//! Together with the applog snapshot (packed side by side by
//! [`crate::applog::persist::to_bytes_with_session`]) this is the whole
//! hibernation image of a session: a rehydrated engine is
//! indistinguishable from one that never slept — same values, same
//! watermark continuity, `rows_replayed == 0` on its next delta
//! extraction.
//!
//! Layout (all multi-byte integers varint/zigzag unless noted, `f64`s
//! raw IEEE bits — see [`crate::util::wire`]):
//!
//! ```text
//! magic "AFSS" | version=2 u16 | blob_len u32 |
//! codec u8 | raw_len varint | compressed payload |
//! crc32 u32   (IEEE, over everything before it)
//!
//! payload (before compression):
//! plan_fingerprint u64 | feature_count varint | flags u8 |
//! [ last_now ] [ last_values: ts, value* ] |
//! [ adaptive: allow_incremental u8, cfg_bits u8, replans varint,
//!   cost-model state ] |
//! lane_count | ( event_type, watermark, row_count,
//!                ( ts, seq, attr_count, (attr_id, tagged value)* )* )* |
//! [ inc bank: synced flag [+ ts], ( present u8 [+ state] )* ]
//! ```
//!
//! The adaptive block (flag `1 << 3`) sits *before* the lanes so decode
//! can reconstruct the session's overlay plan — re-lowered from
//! `cfg_bits` over the shared compiled plan — and validate the inc bank
//! against the **active** plan's aggregation modes, not the base's. The
//! fingerprint field always pins the *base* plan: the overlay is
//! derivable (base plan + cfg bits), so a hibernated adaptive session
//! rehydrates under any sibling of the same compilation. The replan diff
//! log is observability-only and deliberately not serialized; the cost
//! model state is, so pre-sleep statistics seed the post-wake model.
//!
//! v2 runs the payload through the same per-block codec probe as sealed
//! applog segments ([`crate::applog::blockcodec`]) — cached lanes repeat
//! attr ids and string values heavily, so hibernation images shrink for
//! free and a fleet holds more hibernated sessions per byte. v1 blobs
//! (same payload, uncompressed, directly after `blob_len`) still decode.
//!
//! The embedded plan fingerprint pins the blob to the exact lowered
//! [`crate::optimizer::lower::ExecPlan`]: state hibernated under one
//! compilation never silently feeds a different one. Lanes serialize in
//! ascending event-type order so exporting the same state twice yields
//! identical bytes.
//!
//! [`IncrementalState`]: crate::features::incremental::IncrementalState

use anyhow::{bail, ensure, Result};

use crate::applog::blockcodec::{self, BlockCodec, CodecPolicy};
use crate::applog::event::{AttrValue, TimestampMs};
use crate::cache::entry::{CachedLane, CachedRow};
use crate::cache::store::CacheStore;
use crate::features::incremental::IncrementalState;
use crate::features::value::FeatureValue;
use crate::optimizer::cost::{CostConfig, CostModel, StrategySpace};
use crate::optimizer::lower::{lower, AggMode, LowerConfig};
use crate::util::wire;

use super::exec::delta::IncBank;
use super::offline::CompiledEngine;
use super::online::Adaptive;

const MAGIC: &[u8; 4] = b"AFSS";
const VERSION_V1: u16 = 1;
const VERSION_V2: u16 = 2;

const FLAG_LAST_NOW: u8 = 1 << 0;
const FLAG_LAST_VALUES: u8 = 1 << 1;
const FLAG_INC: u8 = 1 << 2;
const FLAG_ADAPTIVE: u8 = 1 << 3;

/// The decoded session-private mutable state, handed back to the engine.
pub(crate) struct SessionState {
    pub cache: CacheStore,
    pub last_now: Option<TimestampMs>,
    pub last_values: Option<(TimestampMs, Vec<FeatureValue>)>,
    pub inc: Option<IncBank>,
    pub adaptive: Option<Adaptive>,
}

pub(crate) fn encode(
    compiled: &CompiledEngine,
    cache: &CacheStore,
    last_now: Option<TimestampMs>,
    last_values: &Option<(TimestampMs, Vec<FeatureValue>)>,
    inc: &Option<IncBank>,
    adaptive: &Option<Adaptive>,
) -> Vec<u8> {
    // Build the uncompressed payload first; the codec probe wraps it.
    let mut out = Vec::new();
    out.extend_from_slice(&compiled.exec.fingerprint.to_le_bytes());
    wire::put_varint(&mut out, compiled.plan.features.len() as u64);
    let mut flags = 0u8;
    if last_now.is_some() {
        flags |= FLAG_LAST_NOW;
    }
    if last_values.is_some() {
        flags |= FLAG_LAST_VALUES;
    }
    if inc.is_some() {
        flags |= FLAG_INC;
    }
    if adaptive.is_some() {
        flags |= FLAG_ADAPTIVE;
    }
    out.push(flags);
    if let Some(t) = last_now {
        wire::put_varint_i64(&mut out, t);
    }
    if let Some((t, values)) = last_values {
        wire::put_varint_i64(&mut out, *t);
        for v in values {
            put_value(&mut out, v);
        }
    }
    if let Some(a) = adaptive {
        out.push(a.cost.space().allow_incremental as u8);
        out.push(a.cfg.to_bits());
        wire::put_varint(&mut out, a.replans);
        a.cost.write_state(&mut out);
    }
    let lanes = cache.lanes_sorted();
    wire::put_varint(&mut out, lanes.len() as u64);
    for lane in lanes {
        wire::put_varint(&mut out, lane.event_type as u64);
        wire::put_varint_i64(&mut out, lane.watermark);
        wire::put_varint(&mut out, lane.rows.len() as u64);
        for row in &lane.rows {
            wire::put_varint_i64(&mut out, row.ts);
            wire::put_varint(&mut out, row.seq);
            wire::put_varint(&mut out, row.attrs.len() as u64);
            for (id, v) in &row.attrs {
                wire::put_varint(&mut out, *id as u64);
                match v {
                    AttrValue::Int(x) => {
                        out.push(0);
                        wire::put_varint_i64(&mut out, *x);
                    }
                    AttrValue::Float(x) => {
                        out.push(1);
                        wire::put_f64(&mut out, *x);
                    }
                    AttrValue::Str(s) => {
                        out.push(2);
                        wire::put_bytes(&mut out, s.as_bytes());
                    }
                }
            }
        }
    }
    if let Some(bank) = inc {
        match bank.synced_at {
            None => out.push(0),
            Some(t) => {
                out.push(1);
                wire::put_varint_i64(&mut out, t);
            }
        }
        for state in &bank.states {
            match state {
                None => out.push(0),
                Some(st) => {
                    out.push(1);
                    st.write_state(&mut out);
                }
            }
        }
    }
    let (codec, enc) = blockcodec::encode_block(CodecPolicy::Probe, &out);
    let mut blob = Vec::with_capacity(enc.len() + 24);
    blob.extend_from_slice(MAGIC);
    blob.extend_from_slice(&VERSION_V2.to_le_bytes());
    blob.extend_from_slice(&0u32.to_le_bytes()); // blob_len, patched below
    blob.push(codec.tag());
    wire::put_varint(&mut blob, out.len() as u64);
    blob.extend_from_slice(&enc);
    let blob_len = (blob.len() + 4) as u32;
    blob[6..10].copy_from_slice(&blob_len.to_le_bytes());
    let crc = wire::crc32(&blob);
    blob.extend_from_slice(&crc.to_le_bytes());
    blob
}

/// Decode a session-state blob against `compiled` (the plan the session
/// must resume under) with `budget` as the restored cache's byte budget.
/// Length, CRC and the plan fingerprint are verified before any parsing,
/// so a damaged or mismatched blob is rejected instead of rehydrating a
/// silently wrong session.
pub(crate) fn decode(
    compiled: &CompiledEngine,
    budget: usize,
    data: &[u8],
) -> Result<SessionState> {
    ensure!(data.len() >= 14, "truncated session-state header");
    ensure!(&data[..4] == MAGIC, "bad session-state magic");
    let version = u16::from_le_bytes(data[4..6].try_into().unwrap());
    ensure!(
        version == VERSION_V1 || version == VERSION_V2,
        "unsupported session-state version {version}"
    );
    let declared = u32::from_le_bytes(data[6..10].try_into().unwrap()) as usize;
    ensure!(
        declared == data.len(),
        "session-state length mismatch: header says {declared}, blob is {}",
        data.len()
    );
    let outer = &data[..data.len() - 4];
    let stored_crc = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    let actual = wire::crc32(outer);
    ensure!(
        stored_crc == actual,
        "session-state checksum mismatch (stored {stored_crc:08x}, computed {actual:08x})"
    );

    // v1 carries the payload raw after the header; v2 wraps it in a
    // probed block codec. Either way parsing below sees plain payload
    // bytes from offset 0.
    let decompressed: Vec<u8>;
    let body: &[u8] = if version == VERSION_V2 {
        let hp = &mut 10usize;
        let codec = BlockCodec::from_tag(wire::get_u8(outer, hp)?)?;
        let raw_len = wire::get_varint(outer, hp)? as usize;
        decompressed = blockcodec::decompress(codec, &outer[*hp..], raw_len)?;
        &decompressed
    } else {
        &outer[10..]
    };
    let pos = &mut 0usize;
    let fp = u64::from_le_bytes(wire::take(body, pos, 8)?.try_into().unwrap());
    ensure!(
        fp == compiled.exec.fingerprint,
        "session state was hibernated under plan {fp:016x}, resuming under {:016x}",
        compiled.exec.fingerprint
    );
    let features = &compiled.plan.features;
    let n_features = wire::get_varint(body, pos)? as usize;
    ensure!(
        n_features == features.len(),
        "session state has {n_features} features, plan has {}",
        features.len()
    );
    let flags = wire::get_u8(body, pos)?;
    ensure!(
        flags & !(FLAG_LAST_NOW | FLAG_LAST_VALUES | FLAG_INC | FLAG_ADAPTIVE) == 0,
        "unknown state flags"
    );

    let last_now = if flags & FLAG_LAST_NOW != 0 {
        Some(wire::get_varint_i64(body, pos)?)
    } else {
        None
    };
    let last_values = if flags & FLAG_LAST_VALUES != 0 {
        let t = wire::get_varint_i64(body, pos)?;
        let mut values = Vec::new();
        for _ in 0..n_features {
            values.push(get_value(body, pos)?);
        }
        Some((t, values))
    } else {
        None
    };

    // The adaptive block precedes the lanes so the overlay plan exists
    // before the inc bank is validated against its aggregation modes.
    let adaptive = if flags & FLAG_ADAPTIVE != 0 {
        let allow_incremental = wire::get_u8(body, pos)? != 0;
        let bits = wire::get_u8(body, pos)?;
        let lcfg = LowerConfig::from_bits(bits);
        let replans = wire::get_varint(body, pos)?;
        let cost = CostModel::read_state(
            CostConfig::default(),
            StrategySpace { allow_incremental },
            compiled.span_ms(),
            body,
            pos,
        )?;
        // Re-lower the overlay from the shared plan; when the bits still
        // describe the compiled base the overlay stays empty.
        let lowered = lower(&compiled.plan, &lcfg);
        let exec = (lowered.fingerprint != compiled.exec.fingerprint).then_some(lowered);
        Some(Adaptive {
            cfg: lcfg,
            exec,
            cost,
            replans,
            log: Vec::new(),
        })
    } else {
        None
    };

    let mut cache = CacheStore::new(budget);
    let lane_count = wire::get_varint(body, pos)? as usize;
    let mut prev_type: Option<u16> = None;
    for _ in 0..lane_count {
        let t = wire::get_varint(body, pos)?;
        ensure!(t <= u16::MAX as u64, "lane event type {t} out of range");
        let t = t as u16;
        ensure!(
            prev_type.is_none_or(|p| p < t),
            "cache lanes not in ascending type order"
        );
        prev_type = Some(t);
        let watermark = wire::get_varint_i64(body, pos)?;
        let row_count = wire::get_varint(body, pos)? as usize;
        let mut lane = CachedLane::new(t, watermark);
        let mut prev_key: Option<(TimestampMs, u64)> = None;
        for _ in 0..row_count {
            let ts = wire::get_varint_i64(body, pos)?;
            let seq = wire::get_varint(body, pos)?;
            ensure!(
                prev_key.is_none_or(|p| p < (ts, seq)),
                "cache rows not chronological"
            );
            prev_key = Some((ts, seq));
            let attr_count = wire::get_varint(body, pos)? as usize;
            let mut attrs = Vec::new();
            for _ in 0..attr_count {
                let id = wire::get_varint(body, pos)?;
                ensure!(id <= u16::MAX as u64, "attr id {id} out of range");
                let v = match wire::get_u8(body, pos)? {
                    0 => AttrValue::Int(wire::get_varint_i64(body, pos)?),
                    1 => AttrValue::Float(wire::get_f64(body, pos)?),
                    2 => {
                        let bytes = wire::get_bytes(body, pos)?;
                        AttrValue::Str(String::from_utf8(bytes.to_vec())?)
                    }
                    tag => bail!("unknown attr value tag {tag}"),
                };
                attrs.push((id as u16, v));
            }
            lane.push(CachedRow { ts, seq, attrs });
        }
        cache.restore_lane(lane);
    }

    let inc = if flags & FLAG_INC != 0 {
        let synced_at = if wire::get_u8(body, pos)? != 0 {
            Some(wire::get_varint_i64(body, pos)?)
        } else {
            None
        };
        // Persistent slots are pinned to the *active* plan's aggregation
        // modes: an adaptive session that re-lowered to incremental-delta
        // hibernates banks the base plan doesn't know about.
        let active_agg = adaptive
            .as_ref()
            .and_then(|a| a.exec.as_ref())
            .map_or(&compiled.exec.agg_modes, |e| &e.agg_modes);
        let mut states = Vec::new();
        for (i, spec) in features.iter().enumerate() {
            if wire::get_u8(body, pos)? != 0 {
                ensure!(
                    matches!(active_agg[i], AggMode::Persistent),
                    "persistent state for one-shot feature '{}'",
                    spec.name
                );
                states.push(Some(IncrementalState::read_state(spec, body, pos)?));
            } else {
                states.push(None);
            }
        }
        Some(IncBank { synced_at, states })
    } else {
        None
    };

    ensure!(
        *pos == body.len(),
        "trailing garbage after session state ({} bytes)",
        body.len() - *pos
    );
    Ok(SessionState {
        cache,
        last_now,
        last_values,
        inc,
        adaptive,
    })
}

fn put_value(out: &mut Vec<u8>, v: &FeatureValue) {
    match v {
        FeatureValue::Scalar(x) => {
            out.push(0);
            wire::put_f64(out, *x);
        }
        FeatureValue::Vector(xs) => {
            out.push(1);
            wire::put_varint(out, xs.len() as u64);
            for x in xs {
                wire::put_f64(out, *x);
            }
        }
    }
}

fn get_value(data: &[u8], pos: &mut usize) -> Result<FeatureValue> {
    match wire::get_u8(data, pos)? {
        0 => Ok(FeatureValue::Scalar(wire::get_f64(data, pos)?)),
        1 => {
            let n = wire::get_varint(data, pos)? as usize;
            let mut xs = Vec::new();
            for _ in 0..n {
                xs.push(wire::get_f64(data, pos)?);
            }
            Ok(FeatureValue::Vector(xs))
        }
        tag => bail!("unknown feature value tag {tag}"),
    }
}
