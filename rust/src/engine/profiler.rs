//! Offline per-type profiling (paper §3.4 "static term" / Fig. 17a).
//!
//! For every behavior type the engine touches, measure once, offline:
//! * `Cost_Opt` — Retrieve+Decode nanoseconds per event (what caching a
//!   row saves on the next execution),
//! * `Size`     — cached bytes per event (attr-union projection).
//!
//! The probes run on schema-sampled synthetic events so profiling needs
//! no user data and completes in milliseconds (Fig. 17a's dominant but
//! small "profiling" bar).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::util::rng::SimRng;
use crate::applog::codec::AttrCodec;
use crate::applog::event::{AttrId, EventTypeId};
use crate::applog::schema::Catalog;
use crate::cache::entry::CachedRow;
use crate::cache::valuation::StaticTerm;

/// Profiled constants for every relevant behavior type.
#[derive(Debug, Clone)]
pub struct ProfileTable {
    per_type: HashMap<EventTypeId, StaticTerm>,
    /// Wall time of the whole profiling pass (Fig. 17a).
    pub profile_time_ns: u64,
}

impl ProfileTable {
    /// Static term for a type (panics if the type wasn't profiled —
    /// offline compilation profiles every type the plan touches).
    pub fn stat(&self, t: EventTypeId) -> &StaticTerm {
        &self.per_type[&t]
    }

    /// Whether a type was profiled.
    pub fn contains(&self, t: EventTypeId) -> bool {
        self.per_type.contains_key(&t)
    }

    /// Number of profiled types.
    pub fn len(&self) -> usize {
        self.per_type.len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.per_type.is_empty()
    }
}

/// Number of synthetic probe events per type.
const PROBE_EVENTS: usize = 24;

/// Profile all types in `attr_unions` (type → union of needed attrs).
pub fn profile(
    catalog: &Catalog,
    codec: &dyn AttrCodec,
    attr_unions: &HashMap<EventTypeId, Vec<AttrId>>,
) -> Result<ProfileTable> {
    let t_start = Instant::now();
    let mut rng = SimRng::seed_from_u64(0x50F1);
    let mut per_type = HashMap::with_capacity(attr_unions.len());

    for (&t, union) in attr_unions {
        let schema = catalog.schema(t);
        // Synthesize probe rows.
        let samples: Vec<Vec<u8>> = (0..PROBE_EVENTS)
            .map(|_| codec.encode(&schema.sample_attrs(&mut rng)))
            .collect();

        // Cost_Opt probe: retrieve (payload copy) + decode per event.
        let t0 = Instant::now();
        let mut cached_bytes = 0usize;
        for payload in &samples {
            let copied = payload.clone(); // the Retrieve data movement
            let attrs = codec.decode(&copied)?;
            // Projection onto the union (what the cache would store).
            let row = CachedRow {
                ts: 0,
                seq: 0,
                attrs: attrs
                    .into_iter()
                    .filter(|(a, _)| union.binary_search(a).is_ok())
                    .collect(),
            };
            cached_bytes += row.approx_size();
        }
        let elapsed = t0.elapsed().as_nanos() as f64;
        per_type.insert(
            t,
            StaticTerm {
                cost_opt_ns_per_event: elapsed / PROBE_EVENTS as f64,
                bytes_per_event: cached_bytes as f64 / PROBE_EVENTS as f64,
            },
        );
    }

    Ok(ProfileTable {
        per_type,
        profile_time_ns: t_start.elapsed().as_nanos() as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::codec::{BinaryCodec, JsonishCodec};
    use crate::applog::schema::CatalogConfig;

    fn unions(types: &[u16], attrs: Vec<u16>) -> HashMap<EventTypeId, Vec<AttrId>> {
        types.iter().map(|&t| (t, attrs.clone())).collect()
    }

    #[test]
    fn profiles_every_requested_type() {
        let cat = Catalog::generate(&CatalogConfig::small(), 1);
        let table = profile(&cat, &JsonishCodec, &unions(&[0, 2, 4], vec![0, 1])).unwrap();
        assert_eq!(table.len(), 3);
        for t in [0u16, 2, 4] {
            let s = table.stat(t);
            assert!(s.cost_opt_ns_per_event > 0.0);
            assert!(s.bytes_per_event > 0.0);
        }
        assert!(table.profile_time_ns > 0);
    }

    #[test]
    fn bigger_schemas_cost_more_to_decode() {
        // Heavy-tail types (more attrs) must profile as more expensive.
        let cat = Catalog::generate(&CatalogConfig::paper(), 2);
        let (small_t, big_t) = {
            let mut idx: Vec<_> = (0..cat.len() as u16).collect();
            idx.sort_by_key(|&t| cat.schema(t).attrs.len());
            (idx[0], *idx.last().unwrap())
        };
        let table = profile(&cat, &JsonishCodec, &unions(&[small_t, big_t], vec![0])).unwrap();
        assert!(
            table.stat(big_t).cost_opt_ns_per_event
                > table.stat(small_t).cost_opt_ns_per_event,
            "decode cost must grow with attribute count"
        );
    }

    #[test]
    fn binary_codec_profiles_cheaper_than_jsonish() {
        let cat = Catalog::generate(&CatalogConfig::paper(), 3);
        let u = unions(&[0], vec![0, 1]);
        let j = profile(&cat, &JsonishCodec, &u).unwrap();
        let b = profile(&cat, &BinaryCodec, &u).unwrap();
        assert!(
            b.stat(0).cost_opt_ns_per_event < j.stat(0).cost_opt_ns_per_event,
            "binary {} >= jsonish {}",
            b.stat(0).cost_opt_ns_per_event,
            j.stat(0).cost_opt_ns_per_event
        );
    }
}
