//! The `WindowSlice → Aggregate` stages of
//! [`Strategy::IncrementalDelta`] pipelines: persistent per-feature
//! state banks fed only the inter-trigger boundary delta.
//!
//! Per member (feature × lane) with window `w`, between the previous
//! sync `prev` and the trigger `now`:
//! * **retract** the rows whose age crossed the member's lower
//!   boundary — timestamps in `[prev − w, now − w)`, found in the
//!   expired prefix plus the retained cached prefix (already isolated
//!   by `prune_before` and the lane ordering);
//! * **push** the fresh rows at/above the boundary (`ts ≥ now − w`).
//!
//! The delta path is valid for a feature only if every backing lane
//! survived in the cache since the previous extraction (watermark ==
//! previous trigger). Otherwise — cold start, policy eviction, budget
//! shrink — the state is rebuilt from the full window
//! ([`FeedMode::Rebuild`]); this is also the exact-recompute fallback
//! when a bounded auxiliary structure reports
//! [`IncrementalState::is_dirty`] after the delta. Either way the state
//! ends the extraction synchronized to `now`, bit-equivalent to a fresh
//! rebuild (modulo float associativity, covered by the 1e-9
//! differential bar).
//!
//! Which features run persistently is **not decided here**: lowering
//! annotated every feature with an [`AggMode`] (from the one shared
//! eligibility predicate), and [`IncBank::for_plan`] instantiates
//! exactly those states.
//!
//! [`Strategy::IncrementalDelta`]: crate::optimizer::lower::Strategy::IncrementalDelta

use std::collections::HashMap;
use std::time::Instant;

use crate::applog::event::{EventTypeId, TimestampMs};
use crate::features::incremental::IncrementalState;
use crate::features::spec::FeatureSpec;
use crate::features::value::FeatureValue;
use crate::optimizer::hierarchical::lookup;
use crate::optimizer::lower::{AggMode, ExecPlan, Stage};
use crate::optimizer::plan::FeatureAcc;

use super::super::offline::CompiledEngine;
use super::materialize::{window_rows, TypeRows};
use super::pipeline::ExecCounters;

/// How one feature's `Aggregate` runs this extraction.
#[derive(Debug, Clone, Copy, PartialEq)]
enum FeedMode {
    /// Persistent state valid: apply only the inter-trigger delta.
    Delta,
    /// Persistent state missing/invalidated (cold start, lane evicted
    /// by policy or budget shrink): rebuild it from the full window.
    Rebuild,
    /// [`AggMode::OneShot`] annotation (multi-lane `Concat`): classic
    /// one-shot accumulator.
    Oneshot,
}

/// Persistent per-feature incremental compute state (kept beside the
/// cache; dies with it on [`crate::engine::Extractor::reset`]).
pub(crate) struct IncBank {
    /// Trigger time the states are synchronized to (`None` until the
    /// first delta extraction completes).
    pub synced_at: Option<TimestampMs>,
    /// One slot per plan feature; `None` = one-shot only.
    pub states: Vec<Option<IncrementalState>>,
}

impl IncBank {
    /// Instantiate the bank from the lowered plan's per-feature
    /// [`AggMode`] annotations — lowering is the single point that
    /// decided persistence eligibility.
    pub(crate) fn for_plan(exec: &ExecPlan, features: &[FeatureSpec]) -> IncBank {
        IncBank {
            synced_at: None,
            states: exec
                .agg_modes
                .iter()
                .zip(features)
                .map(|(mode, spec)| match mode {
                    AggMode::Persistent => IncrementalState::for_spec(spec),
                    AggMode::OneShot => None,
                })
                .collect(),
        }
    }
}

/// Run the delta stages over the materialized row sets.
///
/// Returns one `Some(value)` per persistently computed feature; `None`
/// marks features left to their one-shot sink.
///
/// Cost note: the rebuild/one-shot fallbacks feed per (member, row)
/// with a per-attr binary search, without the fused walker's shared
/// merge-join — `O(members × window)` where the classic walk pays
/// `O(window)` per lane. That is deliberate: rebuilds only run on cold
/// start, lane eviction, or aux-set exhaustion, and sharing the
/// steady-state delta machinery keeps the two paths bit-equivalent. A
/// session that expects frequent evictions should simply run the
/// cached-rewalk strategy.
#[allow(clippy::too_many_arguments)]
pub(crate) fn feed(
    compiled: &CompiledEngine,
    exec: &ExecPlan,
    avail: &HashMap<EventTypeId, TypeRows>,
    now: TimestampMs,
    inc: &mut Option<IncBank>,
    sinks: &mut [FeatureAcc],
    c: &mut ExecCounters,
) -> Vec<Option<FeatureValue>> {
    let plan = &compiled.plan;
    let t0 = Instant::now();
    // `exec` is the *active* plan (a replanned session's overlay): its
    // AggMode annotations, not the compiled base plan's, decide which
    // features run persistently.
    let bank = inc.get_or_insert_with(|| IncBank::for_plan(exec, &plan.features));
    let prev = bank.synced_at;
    // Per-operator tallies, flushed into the counter table at the end
    // (keeps the per-row hot loops on plain integer adds).
    let mut slice_ns = 0u64;
    let mut rows_delta = 0u64;
    let mut rows_replayed = 0u64;

    let modes: Vec<FeedMode> = plan
        .features
        .iter()
        .zip(&bank.states)
        .map(|(spec, st)| {
            if st.is_none() {
                FeedMode::Oneshot
            } else if prev.is_some()
                && spec
                    .event_types
                    .iter()
                    .all(|t| avail.get(t).is_some_and(|r| r.resumed == prev))
            {
                FeedMode::Delta
            } else {
                FeedMode::Rebuild
            }
        })
        .collect();
    for (mode, st) in modes.iter().zip(bank.states.iter_mut()) {
        if let Some(st) = st {
            match mode {
                FeedMode::Delta => st.rebase(now),
                FeedMode::Rebuild => st.reset(now),
                FeedMode::Oneshot => {}
            }
        }
    }

    // Delta iff every lane survived, so `prev` is set for Delta.
    let prev_now = prev.unwrap_or(now);
    for lane in &plan.lanes {
        let rows = &avail[&lane.event_type];
        for group in &lane.groups {
            let w = group.window.duration_ms;
            let new_lo = now - w;
            let old_lo = prev_now - w;
            // WindowSlice: boundary slices depend only on the group's
            // window — one set of binary searches shared by every
            // member (the same per-group sharing the hierarchical
            // walker exploits). Crossing rows (`[old_lo, new_lo)`) live
            // in the expired slice plus the retained cached prefix; the
            // member's current window is the cached suffix plus the
            // fresh suffix.
            let ts = Instant::now();
            let es = rows.expired.partition_point(|r| r.ts < old_lo);
            let ee = rows.expired.partition_point(|r| r.ts < new_lo);
            let cs = rows.cached.rows.partition_point(|r| r.ts < old_lo);
            let ce = rows.cached.rows.partition_point(|r| r.ts < new_lo);
            let fs = rows.fresh.partition_point(|r| r.ts < new_lo);
            slice_ns += ts.elapsed().as_nanos() as u64;
            for m in &group.members {
                match modes[m.feature_idx] {
                    FeedMode::Delta => {
                        let st = bank.states[m.feature_idx].as_mut().unwrap();
                        for r in rows.expired[es..ee]
                            .iter()
                            .chain(rows.cached.rows.range(cs..ce))
                        {
                            rows_delta += 1;
                            for &a in &m.attrs {
                                if let Some(v) = lookup(&r.attrs, a) {
                                    st.retract(r.ts, r.seq, v);
                                }
                            }
                        }
                        for r in &rows.fresh[fs..] {
                            rows_delta += 1;
                            for &a in &m.attrs {
                                if let Some(v) = lookup(&r.attrs, a) {
                                    st.push(r.ts, r.seq, v);
                                }
                            }
                        }
                    }
                    FeedMode::Rebuild => {
                        let st = bank.states[m.feature_idx].as_mut().unwrap();
                        for r in rows
                            .cached
                            .rows
                            .range(ce..)
                            .chain(rows.fresh[fs..].iter())
                        {
                            rows_replayed += 1;
                            for &a in &m.attrs {
                                if let Some(v) = lookup(&r.attrs, a) {
                                    st.push(r.ts, r.seq, v);
                                }
                            }
                        }
                    }
                    FeedMode::Oneshot => {
                        let sink = &mut sinks[m.feature_idx];
                        for r in rows
                            .cached
                            .rows
                            .range(ce..)
                            .chain(rows.fresh[fs..].iter())
                        {
                            rows_replayed += 1;
                            for &a in &m.attrs {
                                if let Some(v) = lookup(&r.attrs, a) {
                                    sink.push(r.ts, r.seq, v);
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    // Exact-recompute fallback: any state whose bounded structure was
    // exhausted by the delta rebuilds from the cached window.
    // Self-healing and test-observable (rows_replayed > 0) — the
    // release-mode replacement for a debug assert.
    for i in 0..plan.features.len() {
        let needs_repair = matches!(modes[i], FeedMode::Delta)
            && bank.states[i].as_ref().is_some_and(|st| st.is_dirty());
        if !needs_repair {
            continue;
        }
        let st = bank.states[i].as_mut().unwrap();
        st.reset(now);
        for lane in &plan.lanes {
            let rows = &avail[&lane.event_type];
            for group in &lane.groups {
                let new_lo = now - group.window.duration_ms;
                for m in &group.members {
                    if m.feature_idx != i {
                        continue;
                    }
                    for r in window_rows(rows, new_lo) {
                        rows_replayed += 1;
                        for &a in &m.attrs {
                            if let Some(v) = lookup(&r.attrs, a) {
                                st.push(r.ts, r.seq, v);
                            }
                        }
                    }
                }
            }
        }
    }

    bank.synced_at = Some(now);

    // Flush operator counters. The delta is WindowSlice's output and
    // Aggregate's input; full-path row visits (rebuild/one-shot/repair)
    // are Filter rows-in, exactly like a classic lane walk.
    let total_ns = t0.elapsed().as_nanos() as u64;
    let ws = c.stage_mut(Stage::WindowSlice);
    ws.ns += slice_ns;
    ws.rows_out += rows_delta;
    let f = c.stage_mut(Stage::Filter);
    f.rows_in += rows_replayed;
    let a = c.stage_mut(Stage::Aggregate);
    a.ns += total_ns.saturating_sub(slice_ns);
    a.rows_in += rows_delta + rows_replayed;

    // Emit (persistent half): snapshot the state banks.
    let t1 = Instant::now();
    let values: Vec<Option<FeatureValue>> = bank
        .states
        .iter()
        .map(|st| st.as_ref().map(|s| s.snapshot()))
        .collect();
    let e = c.stage_mut(Stage::Emit);
    e.ns += t1.elapsed().as_nanos() as u64;
    values
}

#[cfg(test)]
mod tests {
    use crate::applog::codec::{CodecKind, JsonishCodec};
    use crate::applog::schema::{Catalog, CatalogConfig};
    use crate::applog::store::{AppLogStore, StoreConfig};
    use crate::baseline::naive::NaiveExtractor;
    use crate::engine::config::EngineConfig;
    use crate::engine::exec::testutil::setup;
    use crate::engine::online::Engine;
    use crate::engine::Extractor;
    use crate::features::catalog::{generate_feature_set, FeatureSetConfig};
    use crate::features::spec::{FeatureSpec, TimeRange};

    #[test]
    fn incremental_steady_state_is_delta_bound() {
        // Single-type feature sets are fully supported by the persistent
        // path: once warm, every extraction must do O(Δ) compute work —
        // zero full-path row visits outside the (rare, self-healing)
        // aux-set repairs — while staying exact vs the naive oracle.
        let (cat, _, store) = setup();
        let specs = generate_feature_set(
            &cat,
            &FeatureSetConfig {
                num_features: 24,
                num_types: 6,
                identical_share: 0.6,
                windows: vec![TimeRange::mins(5), TimeRange::mins(30)],
                multi_type_prob: 0.0, // single-lane features only
                seed: 99,
            },
        );
        // Roomy budget: every lane stays cached, so the only row visits
        // after warm-up are deltas and (rare) aux repairs.
        let roomy = EngineConfig {
            cache_budget_bytes: 4 << 20,
            ..EngineConfig::incremental()
        };
        let mut inc = Engine::new(specs.clone(), &cat, roomy).unwrap();
        let mut full = Engine::new(
            specs.clone(),
            &cat,
            EngineConfig {
                incremental_compute: false,
                ..roomy
            },
        )
        .unwrap();
        let mut naive = NaiveExtractor::new(specs, CodecKind::Jsonish);
        // Warm both engines.
        inc.extract(&store, 30 * 60_000).unwrap();
        full.extract(&store, 30 * 60_000).unwrap();
        let (mut delta, mut replayed, mut full_replayed) = (0u64, 0u64, 0u64);
        for step in 1..=10i64 {
            // 10 s triggers against 5/30-min windows: the crossing +
            // fresh delta is a few percent of the window even after
            // accounting for the per-(member, row) counting unit of
            // `rows_delta` vs the classic per-(lane, row) unit.
            let now = 30 * 60_000 + step * 10_000;
            let ri = inc.extract(&store, now).unwrap();
            let rf = full.extract(&store, now).unwrap();
            let want = naive.extract(&store, now).unwrap();
            for (x, y) in ri.values.iter().zip(&want.values) {
                assert!(x.approx_eq(y, 1e-9), "step {step}: {x:?} vs {y:?}");
            }
            delta += ri.breakdown.rows_delta;
            replayed += ri.breakdown.rows_replayed;
            full_replayed += rf.breakdown.rows_replayed;
        }
        assert!(delta > 0, "delta path never exercised");
        assert!(
            delta + replayed < full_replayed / 2,
            "delta {delta} + replayed {replayed} vs full rewalk {full_replayed}"
        );
    }

    #[test]
    fn idle_type_does_not_defeat_delta_mode() {
        // Regression: empty lanes used to be dropped by the cache
        // update, so a feature spanning a busy type and an idle one
        // (zero in-window rows) lost watermark continuity every trigger
        // and rebuilt its busy lane from the full window — O(window)
        // forever, silently defeating incremental_compute.
        let cat = Catalog::generate(&CatalogConfig::paper(), 42);
        let spec = FeatureSpec {
            id: crate::features::spec::FeatureId(0),
            name: "busy_plus_idle".into(),
            event_types: vec![0, 1], // type 1 never logs an event
            window: TimeRange::mins(5),
            attrs: vec![0],
            comp: crate::features::compute::CompFunc::Sum,
        }
        .normalized();
        let codec = JsonishCodec;
        let mut store = AppLogStore::new(StoreConfig::default());
        for i in 0..1200i64 {
            use crate::applog::codec::AttrCodec;
            store
                .append(
                    0,
                    i * 1_000,
                    codec.encode(&[(0, crate::applog::event::AttrValue::Int(i))]),
                )
                .unwrap();
        }
        let mut eng = Engine::new(vec![spec.clone()], &cat, EngineConfig::incremental()).unwrap();
        let mut naive = NaiveExtractor::new(vec![spec], CodecKind::Jsonish);
        eng.extract(&store, 10 * 60_000).unwrap(); // warm (rebuild)
        for step in 1..=5i64 {
            let now = 10 * 60_000 + step * 10_000;
            let r = eng.extract(&store, now).unwrap();
            assert_eq!(
                r.breakdown.rows_replayed, 0,
                "step {step}: idle type forced a rebuild"
            );
            assert!(r.breakdown.rows_delta > 0, "step {step}");
            let want = naive.extract(&store, now).unwrap();
            for (x, y) in r.values.iter().zip(&want.values) {
                assert!(x.approx_eq(y, 1e-9), "step {step}: {x:?} vs {y:?}");
            }
        }
    }

    #[test]
    fn incremental_rebuilds_after_budget_eviction() {
        // "State dies with its lane": a budget shrink evicts cached
        // lanes; the next extraction must detect the watermark mismatch,
        // rebuild (observable as rows_replayed > 0) and stay exact.
        let (cat, specs, store) = setup();
        let roomy = EngineConfig {
            cache_budget_bytes: 4 << 20,
            ..EngineConfig::incremental()
        };
        let mut eng = Engine::new(specs.clone(), &cat, roomy).unwrap();
        let mut naive = NaiveExtractor::new(specs, CodecKind::Jsonish);
        eng.extract(&store, 30 * 60_000).unwrap();
        eng.extract(&store, 31 * 60_000).unwrap();
        assert!(eng.cache_bytes() > 0);
        eng.set_cache_budget(0, 60_000);
        assert_eq!(eng.cache_bytes(), 0);
        let now = 32 * 60_000;
        let r = eng.extract(&store, now).unwrap();
        assert!(r.breakdown.rows_replayed > 0, "eviction must force a rebuild");
        let want = naive.extract(&store, now).unwrap();
        for (x, y) in r.values.iter().zip(&want.values) {
            assert!(x.approx_eq(y, 1e-9), "{x:?} vs {y:?}");
        }
        // Restore the budget: the path re-warms back to delta-only.
        eng.set_cache_budget(4 << 20, 60_000);
        eng.extract(&store, 33 * 60_000).unwrap();
        let r = eng.extract(&store, 34 * 60_000).unwrap();
        assert!(r.breakdown.rows_delta > 0);
    }

    #[test]
    fn incremental_reset_clears_persistent_state() {
        let (cat, specs, store) = setup();
        let mut eng = Engine::new(specs, &cat, EngineConfig::incremental()).unwrap();
        eng.extract(&store, 30 * 60_000).unwrap();
        assert!(eng.has_incremental_state());
        eng.reset();
        assert!(!eng.has_incremental_state());
        // Post-reset extraction rebuilds cold and stays correct.
        let r = eng.extract(&store, 31 * 60_000).unwrap();
        assert_eq!(r.breakdown.rows_from_cache, 0);
        assert!(r.breakdown.rows_replayed > 0);
    }
}
