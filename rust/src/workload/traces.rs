//! Seeded synthetic user behavior traces (Appendix A reproduction).
//!
//! Events arrive as a per-type Poisson process gated by a session/break
//! duty cycle (night = long uninterrupted sessions). Rates follow
//! [`super::behavior`]; attribute payloads are sampled from the behavior
//! schema and encoded with the store codec at logging time — exactly the
//! paper's Stage 1 ("Behavior Logging").

pub use super::behavior::{ActivityLevel, Period};

use crate::util::rng::SimRng;

use crate::applog::codec::AttrCodec;
use crate::applog::event::{EventTypeId, TimestampMs};
use crate::applog::schema::Catalog;
use crate::applog::store::AppLogStore;

use super::behavior::in_session_rate_per_min;

/// One generated (not yet logged) behavior event.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Event time.
    pub timestamp_ms: TimestampMs,
    /// Behavior type.
    pub event_type: EventTypeId,
    /// Decoded attributes (encoded by [`log_events`] at append time).
    pub attrs: Vec<(u16, crate::applog::event::AttrValue)>,
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Time-of-day period (session model + per-type rates).
    pub period: Period,
    /// User activity percentile.
    pub activity: ActivityLevel,
    /// Trace start time.
    pub start_ms: TimestampMs,
    /// Trace length.
    pub duration_ms: i64,
    /// RNG seed (one per simulated user).
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            period: Period::Night,
            activity: ActivityLevel::P70,
            start_ms: 0,
            duration_ms: 60 * 60_000,
            seed: 0,
        }
    }
}

/// Seeded trace generator.
#[derive(Debug)]
pub struct TraceGenerator<'a> {
    catalog: &'a Catalog,
}

impl<'a> TraceGenerator<'a> {
    /// Create a generator over a behavior catalog.
    pub fn new(catalog: &'a Catalog) -> Self {
        TraceGenerator { catalog }
    }

    /// Generate a chronological event trace.
    pub fn generate(&self, cfg: &TraceConfig) -> Vec<TraceEvent> {
        let mut rng = SimRng::seed_from_u64(cfg.seed);
        let mult = cfg.activity.multiplier();
        let (sess_ms, brk_ms) = cfg.period.session_model();
        let mut events = Vec::new();

        // Walk session/break phases across the trace horizon. Phase
        // lengths jitter ±30% so users desynchronize.
        let mut t = cfg.start_ms;
        let end = cfg.start_ms + cfg.duration_ms;
        let mut in_session = true;
        while t < end {
            let nominal = if in_session { sess_ms } else { brk_ms };
            let phase_len =
                ((nominal as f64) * rng.range_f(0.7, 1.3)).round() as i64;
            let phase_end = (t + phase_len).min(end);
            if in_session {
                // Per-type Poisson arrivals within the session.
                for ty in 0..self.catalog.len() as EventTypeId {
                    let rate_per_ms =
                        in_session_rate_per_min(ty, cfg.period) * mult / 60_000.0;
                    if rate_per_ms <= 0.0 {
                        continue;
                    }
                    let mut ts = t;
                    loop {
                        // Exponential inter-arrival.
                        let u: f64 = rng.range_f(1e-12, 1.0);
                        let gap = (-u.ln() / rate_per_ms).ceil() as i64;
                        ts += gap.max(1);
                        if ts >= phase_end {
                            break;
                        }
                        let schema = self.catalog.schema(ty);
                        events.push(TraceEvent {
                            timestamp_ms: ts,
                            event_type: ty,
                            attrs: schema.sample_attrs(&mut rng),
                        });
                    }
                }
            }
            t = phase_end;
            in_session = !in_session;
        }
        events.sort_by_key(|e| e.timestamp_ms);
        events
    }
}

/// Append a slice of trace events to the app log, encoding attributes
/// with `codec` (Stage 1: behavior logging).
pub fn log_events(
    store: &mut AppLogStore,
    codec: &dyn AttrCodec,
    events: &[TraceEvent],
) -> anyhow::Result<()> {
    for e in events {
        let payload = codec.encode(&e.attrs);
        store.append(e.event_type, e.timestamp_ms, payload)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::codec::JsonishCodec;
    use crate::applog::schema::CatalogConfig;
    use crate::applog::store::StoreConfig;

    fn catalog() -> Catalog {
        Catalog::generate(&CatalogConfig::paper(), 42)
    }

    #[test]
    fn trace_is_chronological_and_deterministic() {
        let cat = catalog();
        let gen = TraceGenerator::new(&cat);
        let cfg = TraceConfig::default();
        let a = gen.generate(&cfg);
        let b = gen.generate(&cfg);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        for w in a.windows(2) {
            assert!(w[0].timestamp_ms <= w[1].timestamp_ms);
        }
    }

    #[test]
    fn activity_levels_scale_volume() {
        let cat = catalog();
        let gen = TraceGenerator::new(&cat);
        let mut counts = Vec::new();
        for activity in ActivityLevel::ALL {
            let cfg = TraceConfig {
                activity,
                seed: 5,
                ..TraceConfig::default()
            };
            counts.push(gen.generate(&cfg).len());
        }
        // Monotone-ish: P90 must far exceed P30.
        assert!(counts[5] > 4 * counts[0], "{counts:?}");
    }

    #[test]
    fn per_10min_totals_match_appendix_bounds() {
        let cat = catalog();
        let gen = TraceGenerator::new(&cat);
        let hour = 60 * 60_000;
        // P90 users: > 45 behaviors / 10 min (averaged over the period).
        let p90 = gen.generate(&TraceConfig {
            activity: ActivityLevel::P90,
            duration_ms: 2 * hour,
            seed: 1,
            ..TraceConfig::default()
        });
        let p90_per10 = p90.len() as f64 / 12.0;
        assert!(p90_per10 > 45.0, "P90 {p90_per10}/10min");
        // P30 users: < 5 behaviors / 10 min.
        let p30 = gen.generate(&TraceConfig {
            activity: ActivityLevel::P30,
            duration_ms: 2 * hour,
            seed: 1,
            ..TraceConfig::default()
        });
        let p30_per10 = p30.len() as f64 / 12.0;
        assert!(p30_per10 < 5.0, "P30 {p30_per10}/10min");
    }

    #[test]
    fn night_has_more_events_than_noon() {
        // §4.2: night = extended uninterrupted engagement -> more newly
        // logged events per wall-clock hour.
        let cat = catalog();
        let gen = TraceGenerator::new(&cat);
        let base = TraceConfig {
            duration_ms: 2 * 60 * 60_000,
            seed: 3,
            ..TraceConfig::default()
        };
        let night = gen
            .generate(&TraceConfig { period: Period::Night, ..base.clone() })
            .len();
        let noon = gen
            .generate(&TraceConfig { period: Period::Noon, ..base.clone() })
            .len();
        assert!(night as f64 > 1.15 * noon as f64, "night={night} noon={noon}");
    }

    #[test]
    fn log_events_appends_in_order() {
        let cat = catalog();
        let gen = TraceGenerator::new(&cat);
        let events = gen.generate(&TraceConfig::default());
        let mut store = AppLogStore::new(StoreConfig::default());
        log_events(&mut store, &JsonishCodec, &events).unwrap();
        assert_eq!(store.len(), events.len());
    }
}
