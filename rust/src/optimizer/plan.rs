//! The optimized execution plan produced by chain fusion.

use crate::applog::event::{AttrId, AttrValue, EventTypeId, TimestampMs};
use crate::features::compute::{CompFunc, ComputeState};
use crate::features::spec::{FeatureSpec, TimeRange};
use crate::features::value::FeatureValue;

/// A feature's membership in a fused lane.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberFeature {
    /// Index of the feature in the plan's spec list.
    pub feature_idx: usize,
    /// Attributes this feature projects from the lane's rows.
    pub attrs: Vec<AttrId>,
    /// Positions of `attrs` within the lane's `attr_union` (precomputed
    /// offline; lets the hierarchical walk index a per-row dense slot
    /// table instead of binary-searching each attribute — §Perf).
    pub attr_slots: Vec<u16>,
}

/// All lane members sharing one `time_range` condition. §3.3's key
/// observation (ii): windows are drawn from a small set of meaningful
/// periodic ranges, so members group naturally.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowGroup {
    /// The shared `time_range`.
    pub window: TimeRange,
    /// Features with exactly this window in this lane.
    pub members: Vec<MemberFeature>,
}

/// One fused execution lane: all sub-chains on one behavior type.
///
/// `Retrieve` runs once per lane over `max_window`; `Decode` runs once
/// per row; the hierarchical filter separates outputs per member without
/// a trailing `Branch` node (branch postposition, §3.3).
#[derive(Debug, Clone, PartialEq)]
pub struct FusedLane {
    /// The lane's single `event_name` condition.
    pub event_type: EventTypeId,
    /// Max window over members: the lane's fused `Retrieve` range.
    pub max_window: TimeRange,
    /// Members grouped by window, ascending by duration (the reverse
    /// mapping of the hierarchical filtering algorithm, precomputed
    /// offline).
    pub groups: Vec<WindowGroup>,
    /// Union of all members' attrs: the projection cached per row by the
    /// event evaluator (§3.4 caches at behavior level).
    pub attr_union: Vec<AttrId>,
}

/// The optimized plan for one model's feature set.
#[derive(Debug, Clone)]
pub struct OptimizedPlan {
    /// The model's feature conditions (index space for `feature_idx`).
    pub features: Vec<FeatureSpec>,
    /// Fused lanes, sorted by event type.
    pub lanes: Vec<FusedLane>,
}

impl OptimizedPlan {
    /// Number of `Retrieve` executions per extraction (= #lanes), the
    /// quantity fusion minimizes: without fusion this is
    /// Σ_features |event_types(f)|.
    pub fn num_retrieves(&self) -> usize {
        self.lanes.len()
    }

    /// Max `Retrieve` window for a behavior type (cache retention
    /// horizon), if the plan touches it.
    pub fn type_window_ms(&self, t: EventTypeId) -> Option<i64> {
        self.lanes
            .iter()
            .filter(|l| l.event_type == t)
            .map(|l| l.max_window.duration_ms)
            .max()
    }
}

/// Per-feature output accumulator used during plan execution — the
/// **one-shot** compute mode: built at the start of an extraction, fed
/// every in-window row, consumed by [`FeatureAcc::finish`].
///
/// Streaming for order-insensitive computations; buffered (sort on
/// finish) for order-sensitive ones (`Concat`) whose feature spans
/// multiple lanes and therefore receives rows out of global order.
///
/// The engine's `incremental_compute` mode replaces this with the
/// **persistent** counterpart
/// [`crate::features::incremental::IncrementalState`], which survives
/// across extractions and is updated only by the inter-trigger delta;
/// features [`FeatureAcc::supports_persistent`] rejects (multi-lane
/// `Concat`) stay on the one-shot path even there.
#[derive(Debug)]
pub enum FeatureAcc {
    /// Streaming accumulator (the common, allocation-free case).
    Stream(ComputeState),
    /// Buffer + sort-on-finish for order-sensitive multi-lane features.
    Buffered {
        /// Collected `(ts, seq, value)` observations.
        pairs: Vec<(TimestampMs, u64, AttrValue)>,
        /// The feature's computation.
        comp: CompFunc,
        /// Extraction trigger time.
        now: TimestampMs,
    },
}

impl FeatureAcc {
    /// Whether the feature can instead be maintained as persistent
    /// incremental state across extractions (the engine's
    /// `incremental_compute` mode).
    pub fn supports_persistent(spec: &FeatureSpec) -> bool {
        crate::features::incremental::IncrementalState::for_spec(spec).is_some()
    }

    /// Create the right one-shot accumulator for a feature. The
    /// buffering decision is [`FeatureSpec::requires_cross_lane_order`]
    /// — the same predicate that disqualifies a feature from the
    /// persistent path, so the two can never diverge.
    pub fn new(spec: &FeatureSpec, now: TimestampMs) -> FeatureAcc {
        if spec.requires_cross_lane_order() {
            FeatureAcc::Buffered {
                pairs: Vec::new(),
                comp: spec.comp,
                now,
            }
        } else {
            FeatureAcc::Stream(spec.comp.accumulator(now))
        }
    }

    /// Feed one observation.
    #[inline]
    pub fn push(&mut self, ts: TimestampMs, seq: u64, value: &AttrValue) {
        match self {
            FeatureAcc::Stream(st) => st.push(ts, seq, value),
            FeatureAcc::Buffered { pairs, .. } => pairs.push((ts, seq, value.clone())),
        }
    }

    /// Produce the feature value.
    pub fn finish(self) -> FeatureValue {
        match self {
            FeatureAcc::Stream(st) => st.finish(),
            FeatureAcc::Buffered { mut pairs, comp, now } => {
                pairs.sort_by_key(|(ts, seq, _)| (*ts, *seq));
                let mut st = comp.accumulator(now);
                for (ts, seq, v) in &pairs {
                    st.push(*ts, *seq, v);
                }
                st.finish()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::spec::FeatureId;

    fn spec(types: Vec<u16>, comp: CompFunc) -> FeatureSpec {
        FeatureSpec {
            id: FeatureId(0),
            name: "t".into(),
            event_types: types,
            window: TimeRange::mins(5),
            attrs: vec![0],
            comp,
        }
        .normalized()
    }

    #[test]
    fn multi_lane_concat_is_buffered_and_sorts() {
        let s = spec(vec![0, 1], CompFunc::Concat { max_len: 3 });
        let mut acc = FeatureAcc::new(&s, 100);
        assert!(matches!(acc, FeatureAcc::Buffered { .. }));
        // Push out of order (lane 1 after lane 0).
        acc.push(30, 3, &AttrValue::Int(30));
        acc.push(10, 1, &AttrValue::Int(10));
        acc.push(20, 2, &AttrValue::Int(20));
        assert_eq!(
            acc.finish(),
            FeatureValue::Vector(vec![10.0, 20.0, 30.0])
        );
    }

    #[test]
    fn single_lane_concat_streams() {
        let s = spec(vec![0], CompFunc::Concat { max_len: 3 });
        assert!(matches!(FeatureAcc::new(&s, 0), FeatureAcc::Stream(_)));
    }

    #[test]
    fn persistent_mode_mirrors_the_buffering_condition() {
        // Exactly the features the one-shot path must buffer are the
        // ones the persistent path cannot maintain. Both decisions now
        // derive from `FeatureSpec::requires_cross_lane_order`; this
        // sweep over every comp function x lane arity documents the
        // contract and catches any future re-divergence (e.g. a new
        // CompFunc wired into only one of the two paths).
        let comps = [
            CompFunc::Count,
            CompFunc::Sum,
            CompFunc::Mean,
            CompFunc::Min,
            CompFunc::Max,
            CompFunc::Latest,
            CompFunc::Earliest,
            CompFunc::DistinctCount,
            CompFunc::Concat { max_len: 3 },
            CompFunc::DecayedSum {
                half_life_ms: 60_000,
            },
        ];
        for comp in comps {
            for types in [vec![0u16], vec![0, 1], vec![0, 1, 2]] {
                let s = spec(types, comp);
                let buffered = matches!(FeatureAcc::new(&s, 0), FeatureAcc::Buffered { .. });
                assert_eq!(
                    buffered,
                    s.requires_cross_lane_order(),
                    "buffering diverged from the shared predicate: {s:?}"
                );
                assert_eq!(
                    FeatureAcc::supports_persistent(&s),
                    !s.requires_cross_lane_order(),
                    "persistent eligibility diverged from the shared predicate: {s:?}"
                );
            }
        }
    }

    #[test]
    fn multi_lane_sum_streams() {
        // Order-insensitive comps never need buffering.
        let s = spec(vec![0, 1, 2], CompFunc::Sum);
        assert!(matches!(FeatureAcc::new(&s, 0), FeatureAcc::Stream(_)));
    }
}
