//! Lowering: from the fused [`OptimizedPlan`] to the **ExecPlan IR** —
//! an explicit, inspectable operator pipeline per fused lane.
//!
//! The optimizer's output used to stop at the lane/group geometry and
//! leave the actual execution shape (cache bridging, rewalk vs delta,
//! hierarchical vs direct filtering) to branches buried inside the
//! online engine. Lowering makes those choices **plan state**: each lane
//! becomes a staged pipeline
//!
//! ```text
//! Scan → Project → Filter [→ WindowSlice] → Aggregate        (per lane)
//!                                            Emit             (per plan)
//! ```
//!
//! with an execution [`Strategy`] chosen once, at lowering time, from
//! the engine configuration:
//!
//! * [`Strategy::OneShot`] — no cross-execution cache: every `Scan`
//!   reads the app log directly ([`ScanSource::Columnar`] — segment
//!   batches from `applog::retrieve_project`, no row materialization).
//! * [`Strategy::CachedRewalk`] — cache-resident lanes plus a
//!   missing-interval scan ([`ScanSource::CacheBridge`]); Filter+
//!   Aggregate rewalk the full window each trigger.
//! * [`Strategy::IncrementalDelta`] — as above, but a `WindowSlice`
//!   operator isolates the inter-trigger boundary slices and `Aggregate`
//!   maintains persistent per-feature states; features that cannot be
//!   maintained incrementally (see
//!   [`crate::features::spec::FeatureSpec::requires_cross_lane_order`])
//!   are annotated
//!   [`AggMode::OneShot`] **here**, so the executor never re-derives the
//!   eligibility predicate.
//!
//! Every operator carries a content [`fingerprint`](OpDesc::fingerprint)
//! (FNV-1a over its descriptor, chained through the pipeline), and
//! [`ExecPlan::explain`] renders the whole plan as deterministic text —
//! the unit the golden plan-snapshot tests pin.

use std::fmt::Write as _;

use crate::applog::event::{AttrId, EventTypeId};

use super::plan::{FeatureAcc, OptimizedPlan};

/// Execution strategy of a lowered plan, fixed at lowering time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// No cross-execution cache: one columnar log scan per lane.
    OneShot,
    /// Cache bridge + full Filter/Aggregate rewalk per trigger.
    CachedRewalk,
    /// Cache bridge + boundary-sliced delta over persistent states.
    IncrementalDelta,
}

impl Strategy {
    /// Display label (stable — part of the explain snapshot format).
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::OneShot => "one-shot",
            Strategy::CachedRewalk => "cached-rewalk",
            Strategy::IncrementalDelta => "incremental-delta",
        }
    }
}

/// Where a `Scan` operator reads its rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanSource {
    /// Straight from the segmented app log (zone-map pruned segment
    /// batches); rows are never materialized as cache entries.
    Columnar,
    /// Cache-resident lane plus a columnar scan of the missing interval;
    /// fresh rows are materialized into the lane for the next trigger.
    CacheBridge,
}

impl ScanSource {
    fn label(&self) -> &'static str {
        match self {
            ScanSource::Columnar => "log",
            ScanSource::CacheBridge => "cache+log",
        }
    }
}

/// Filter implementation of a lane walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterMode {
    /// Monotone boundary pointer over window groups (§3.3, Fig. 11).
    Hierarchical,
    /// Every row tested against every member window (the ablation).
    Direct,
}

impl FilterMode {
    fn label(&self) -> &'static str {
        match self {
            FilterMode::Hierarchical => "hierarchical",
            FilterMode::Direct => "direct",
        }
    }
}

/// Execution grain of a lowered operator: column batches or the classic
/// row-at-a-time walk.
///
/// Lowering annotates every operator with its grain. On the default
/// engine shape the whole uncached pipeline runs at batch grain
/// (`ColumnBatch` + `SelectionVector`, no row materialization); the
/// cache bridge's `Scan`/`Project` stay row grain (cache-resident lanes
/// are materialized rows by design). The full row-walk plan survives
/// only as the differential-test oracle
/// ([`crate::engine::config::EngineConfig::row_walk_exec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Column-batch grain: selection vectors over zero-copy segment
    /// views, per-unique-payload decode, suffix walks per batch.
    Batch,
    /// Row-at-a-time grain over a materialized row stream.
    RowWalk,
}

impl ExecMode {
    /// Display label (stable — part of the explain format).
    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::Batch => "batch",
            ExecMode::RowWalk => "row-walk",
        }
    }
}

/// How one feature's `Aggregate` runs under the plan's strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggMode {
    /// Fresh accumulator per extraction ([`FeatureAcc`]).
    OneShot,
    /// Persistent [`crate::features::incremental::IncrementalState`],
    /// updated by the inter-trigger delta.
    Persistent,
}

/// Pipeline stages, in execution order. Indexes the executor's
/// per-operator counter table and labels explain lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Row acquisition (cache fetch and/or log retrieve).
    Scan,
    /// Payload decode into the attr projection.
    Project,
    /// Window-membership filtering (the lane walk).
    Filter,
    /// Inter-trigger boundary slicing (delta strategy only).
    WindowSlice,
    /// Feeding member accumulators / persistent states.
    Aggregate,
    /// Assembling final feature values.
    Emit,
}

impl Stage {
    /// All stages in pipeline order.
    pub const ALL: [Stage; 6] = [
        Stage::Scan,
        Stage::Project,
        Stage::Filter,
        Stage::WindowSlice,
        Stage::Aggregate,
        Stage::Emit,
    ];

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            Stage::Scan => "Scan",
            Stage::Project => "Project",
            Stage::Filter => "Filter",
            Stage::WindowSlice => "WindowSlice",
            Stage::Aggregate => "Aggregate",
            Stage::Emit => "Emit",
        }
    }
}

/// One typed operator of a lowered pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecOp {
    /// Acquire the lane's rows over its max window.
    Scan {
        /// The lane's behavior type.
        event_type: EventTypeId,
        /// The lane's fused retrieve range (max member window).
        window_ms: i64,
        /// Row source.
        source: ScanSource,
    },
    /// Decode payloads into an attr projection.
    Project {
        /// Projected attrs (the lane's attr union), or `None` for a full
        /// decode of every attribute (the unoptimized baseline shape —
        /// projection then happens at Filter time).
        attrs: Option<Vec<AttrId>>,
    },
    /// Window-membership filtering over the lane's groups.
    Filter {
        /// Walk implementation.
        mode: FilterMode,
        /// Distinct member windows, ascending (the group boundaries).
        windows_ms: Vec<i64>,
        /// Total members across groups.
        members: usize,
    },
    /// Boundary slicing for the delta path: per group window `w`,
    /// isolate rows crossing `[prev - w, now - w)` (retract) and fresh
    /// rows at/above `now - w` (push).
    WindowSlice {
        /// Distinct member windows, ascending.
        windows_ms: Vec<i64>,
    },
    /// Feed qualifying observations into member accumulators.
    Aggregate {
        /// One annotation per lane member, group-major.
        members: Vec<AggMember>,
    },
    /// Assemble final feature values (plan-level, after all pipelines).
    Emit {
        /// Number of features emitted.
        features: usize,
        /// Features emitted from persistent state snapshots.
        persistent: usize,
    },
}

impl ExecOp {
    /// The operator's pipeline stage.
    pub fn stage(&self) -> Stage {
        match self {
            ExecOp::Scan { .. } => Stage::Scan,
            ExecOp::Project { .. } => Stage::Project,
            ExecOp::Filter { .. } => Stage::Filter,
            ExecOp::WindowSlice { .. } => Stage::WindowSlice,
            ExecOp::Aggregate { .. } => Stage::Aggregate,
            ExecOp::Emit { .. } => Stage::Emit,
        }
    }

    /// Fold the operator's descriptor into an FNV-1a fingerprint chain.
    fn fold(&self, h: u64) -> u64 {
        let mut h = fnv_u8(h, self.stage() as u8);
        match self {
            ExecOp::Scan { event_type, window_ms, source } => {
                h = fnv_u64(h, *event_type as u64);
                h = fnv_u64(h, *window_ms as u64);
                h = fnv_u8(h, *source as u8);
            }
            ExecOp::Project { attrs } => match attrs {
                Some(list) => {
                    h = fnv_u64(h, list.len() as u64 + 1);
                    for a in list {
                        h = fnv_u64(h, *a as u64);
                    }
                }
                None => h = fnv_u64(h, 0),
            },
            ExecOp::Filter { mode, windows_ms, members } => {
                h = fnv_u8(h, *mode as u8);
                h = fnv_u64(h, *members as u64);
                for w in windows_ms {
                    h = fnv_u64(h, *w as u64);
                }
            }
            ExecOp::WindowSlice { windows_ms } => {
                for w in windows_ms {
                    h = fnv_u64(h, *w as u64);
                }
            }
            ExecOp::Aggregate { members } => {
                for m in members {
                    h = fnv_u64(h, m.feature_idx as u64);
                    h = fnv_u8(h, m.mode as u8);
                    h = fnv_u64(h, m.attrs.len() as u64);
                    for a in &m.attrs {
                        h = fnv_u64(h, *a as u64);
                    }
                }
            }
            ExecOp::Emit { features, persistent } => {
                h = fnv_u64(h, *features as u64);
                h = fnv_u64(h, *persistent as u64);
            }
        }
        h
    }

    /// Render one explain line (without the leading indent / fp column).
    fn render(&self) -> String {
        match self {
            ExecOp::Scan { event_type, window_ms, source } => format!(
                "Scan        type={event_type} window_ms={window_ms} source={}",
                source.label()
            ),
            ExecOp::Project { attrs } => match attrs {
                Some(list) => format!("Project     attrs={list:?}"),
                None => "Project     attrs=* (full decode)".to_string(),
            },
            ExecOp::Filter { mode, windows_ms, members } => format!(
                "Filter      {} windows_ms={windows_ms:?} members={members}",
                mode.label()
            ),
            ExecOp::WindowSlice { windows_ms } => {
                format!("WindowSlice windows_ms={windows_ms:?}")
            }
            ExecOp::Aggregate { members } => {
                let persistent = members
                    .iter()
                    .filter(|m| m.mode == AggMode::Persistent)
                    .count();
                let attrs: Vec<(usize, &[AttrId])> = members
                    .iter()
                    .map(|m| (m.feature_idx, m.attrs.as_slice()))
                    .collect();
                format!(
                    "Aggregate   members={} persistent={persistent} one-shot={} attrs={attrs:?}",
                    members.len(),
                    members.len() - persistent
                )
            }
            ExecOp::Emit { features, persistent } => format!(
                "Emit        features={features} persistent={persistent} one-shot={}",
                features - persistent
            ),
        }
    }
}

/// One lane member's `Aggregate` annotation. Carries the member's
/// projected attrs so the fingerprint (and explain diff) catches a
/// member being rewired to different attributes even when the lane's
/// attr union is unchanged.
#[derive(Debug, Clone, PartialEq)]
pub struct AggMember {
    /// Index of the feature in the plan's spec list.
    pub feature_idx: usize,
    /// Aggregate mode under the plan's strategy.
    pub mode: AggMode,
    /// Attributes this member projects from the lane's rows.
    pub attrs: Vec<AttrId>,
}

/// An operator plus its chained content fingerprint: FNV-1a over the
/// descriptor, seeded with the upstream operator's fingerprint, so any
/// change anywhere upstream re-fingerprints the whole suffix.
#[derive(Debug, Clone, PartialEq)]
pub struct OpDesc {
    /// The operator.
    pub op: ExecOp,
    /// Execution grain the operator was lowered to.
    pub mode: ExecMode,
    /// Chained content fingerprint.
    pub fingerprint: u64,
}

/// The lowered pipeline of one fused lane.
#[derive(Debug, Clone, PartialEq)]
pub struct LanePipeline {
    /// Index of the lane in the source [`OptimizedPlan::lanes`].
    pub lane_idx: usize,
    /// Operators in stage order.
    pub ops: Vec<OpDesc>,
    /// The pipeline's fingerprint (= its last operator's chain value).
    pub fingerprint: u64,
}

/// The lowered execution plan: what the one pipeline executor runs.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecPlan {
    /// Execution strategy (uniform across pipelines).
    pub strategy: Strategy,
    /// One pipeline per fused lane, in lane order.
    pub pipelines: Vec<LanePipeline>,
    /// Per-feature aggregate mode (index space =
    /// [`OptimizedPlan::features`]). All [`AggMode::OneShot`] outside the
    /// delta strategy.
    pub agg_modes: Vec<AggMode>,
    /// The plan-level emit operator.
    pub emit: OpDesc,
    /// Whole-plan fingerprint.
    pub fingerprint: u64,
}

/// Knobs that shape lowering — the subset of the engine configuration
/// that is *plan structure* rather than per-session state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LowerConfig {
    /// Cross-execution caching: bridges `Scan` through cached lanes.
    pub enable_cache: bool,
    /// Persistent incremental compute (requires `enable_cache`).
    pub incremental_compute: bool,
    /// Hierarchical (vs direct) lane filtering.
    pub hierarchical_filter: bool,
    /// Push the attr-union projection down into `Project` (the engine
    /// shape). `false` = full decode, filter-time projection (the
    /// unoptimized baseline shape).
    pub projected_decode: bool,
    /// Lower operators to the batch-at-a-time executor
    /// ([`ExecMode::Batch`]): selection vectors over zero-copy column
    /// views instead of materialized row streams. Stages that must
    /// consume materialized rows (the cache bridge's `Scan`/`Project`)
    /// fall back to [`ExecMode::RowWalk`]. Requires `projected_decode`
    /// (the batch kernels decode straight into the attr-union
    /// projection); ignored without it.
    pub batch_exec: bool,
}

impl LowerConfig {
    /// The unoptimized-baseline shape: no cache, full decode, direct
    /// filter, row-at-a-time — how `fegraph::exec` lowers per-feature
    /// chains.
    pub fn baseline() -> Self {
        LowerConfig {
            enable_cache: false,
            incremental_compute: false,
            hierarchical_filter: false,
            projected_decode: false,
            batch_exec: false,
        }
    }

    /// The strategy this config lowers to (the same rules [`lower`]
    /// applies — kept as one function so replanning and lowering can
    /// never disagree).
    pub fn strategy(&self) -> Strategy {
        if !self.enable_cache {
            Strategy::OneShot
        } else if self.incremental_compute {
            Strategy::IncrementalDelta
        } else {
            Strategy::CachedRewalk
        }
    }

    /// Pack into one byte (adaptive state blobs; bit order is part of
    /// the AFSS format and must not change).
    pub fn to_bits(&self) -> u8 {
        (self.enable_cache as u8)
            | (self.incremental_compute as u8) << 1
            | (self.hierarchical_filter as u8) << 2
            | (self.projected_decode as u8) << 3
            | (self.batch_exec as u8) << 4
    }

    /// Inverse of [`Self::to_bits`] (bits 5..8 ignored).
    pub fn from_bits(bits: u8) -> Self {
        LowerConfig {
            enable_cache: bits & 1 != 0,
            incremental_compute: bits & 2 != 0,
            hierarchical_filter: bits & 4 != 0,
            projected_decode: bits & 8 != 0,
            batch_exec: bits & 16 != 0,
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv_u8(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV_PRIME)
}

fn fnv_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h = fnv_u8(h, b);
    }
    h
}

/// Lower an optimized plan into the ExecPlan IR under `cfg`.
///
/// Strategy selection (the rules DESIGN.md §ExecPlan documents):
/// * `!enable_cache` → [`Strategy::OneShot`];
/// * `enable_cache && !incremental_compute` → [`Strategy::CachedRewalk`];
/// * `enable_cache && incremental_compute` →
///   [`Strategy::IncrementalDelta`], with per-feature
///   [`AggMode::Persistent`] iff [`FeatureAcc::supports_persistent`] —
///   the single point where persistent eligibility is decided.
pub fn lower(plan: &OptimizedPlan, cfg: &LowerConfig) -> ExecPlan {
    let strategy = cfg.strategy();
    let delta = strategy == Strategy::IncrementalDelta;

    let agg_modes: Vec<AggMode> = plan
        .features
        .iter()
        .map(|f| {
            if delta && FeatureAcc::supports_persistent(f) {
                AggMode::Persistent
            } else {
                AggMode::OneShot
            }
        })
        .collect();

    let filter_mode = if cfg.hierarchical_filter {
        FilterMode::Hierarchical
    } else {
        FilterMode::Direct
    };
    let source = if cfg.enable_cache {
        ScanSource::CacheBridge
    } else {
        ScanSource::Columnar
    };
    // Batch lowering rules: the columnar Scan/Project (and every compute
    // stage) run at batch grain; the cache bridge's Scan/Project consume
    // materialized cache rows and stay row grain. Without
    // `projected_decode` the batch kernels have no attr-union projection
    // to decode into, so the whole plan falls back to the row walk.
    let batch = cfg.batch_exec && cfg.projected_decode;
    let op_mode = |stage: Stage| -> ExecMode {
        if !batch {
            return ExecMode::RowWalk;
        }
        match stage {
            Stage::Scan | Stage::Project => {
                if source == ScanSource::Columnar {
                    ExecMode::Batch
                } else {
                    ExecMode::RowWalk
                }
            }
            _ => ExecMode::Batch,
        }
    };

    let mut plan_fp = fnv_u8(FNV_OFFSET, strategy as u8);
    let pipelines: Vec<LanePipeline> = plan
        .lanes
        .iter()
        .enumerate()
        .map(|(lane_idx, lane)| {
            let windows_ms: Vec<i64> = lane.groups.iter().map(|g| g.window.duration_ms).collect();
            let members: Vec<AggMember> = lane
                .groups
                .iter()
                .flat_map(|g| g.members.iter())
                .map(|m| AggMember {
                    feature_idx: m.feature_idx,
                    mode: agg_modes[m.feature_idx],
                    attrs: m.attrs.clone(),
                })
                .collect();

            let mut ops: Vec<ExecOp> = vec![
                ExecOp::Scan {
                    event_type: lane.event_type,
                    window_ms: lane.max_window.duration_ms,
                    source,
                },
                ExecOp::Project {
                    attrs: cfg.projected_decode.then(|| lane.attr_union.clone()),
                },
                ExecOp::Filter {
                    mode: filter_mode,
                    windows_ms: windows_ms.clone(),
                    members: members.len(),
                },
            ];
            if delta {
                ops.push(ExecOp::WindowSlice { windows_ms });
            }
            ops.push(ExecOp::Aggregate { members });

            let mut chain = fnv_u64(FNV_OFFSET, lane_idx as u64);
            let ops: Vec<OpDesc> = ops
                .into_iter()
                .map(|op| {
                    let mode = op_mode(op.stage());
                    chain = fnv_u8(op.fold(chain), mode as u8);
                    OpDesc {
                        op,
                        mode,
                        fingerprint: chain,
                    }
                })
                .collect();
            plan_fp = fnv_u64(plan_fp, chain);
            LanePipeline {
                lane_idx,
                ops,
                fingerprint: chain,
            }
        })
        .collect();

    let persistent = agg_modes
        .iter()
        .filter(|m| **m == AggMode::Persistent)
        .count();
    let emit_op = ExecOp::Emit {
        features: plan.features.len(),
        persistent,
    };
    let emit_mode = op_mode(Stage::Emit);
    let emit_fp = fnv_u8(emit_op.fold(plan_fp), emit_mode as u8);
    ExecPlan {
        strategy,
        pipelines,
        agg_modes,
        emit: OpDesc {
            op: emit_op,
            mode: emit_mode,
            fingerprint: emit_fp,
        },
        fingerprint: emit_fp,
    }
}

impl ExecPlan {
    /// Deterministic textual rendering of the lowered plan — the golden
    /// plan-snapshot unit and the `autofeature explain` output. Contains
    /// only static plan structure (no runtime measurements), so the
    /// same feature set + config always renders byte-identically.
    pub fn explain(&self) -> String {
        let mut s = String::new();
        let ExecOp::Emit { features, persistent } = &self.emit.op else {
            unreachable!("emit slot always holds Emit")
        };
        writeln!(
            s,
            "ExecPlan strategy={} features={features} persistent={persistent} pipelines={} fp={:016x}",
            self.strategy.label(),
            self.pipelines.len(),
            self.fingerprint
        )
        .unwrap();
        for p in &self.pipelines {
            writeln!(s, "  pipeline[{}] fp={:016x}", p.lane_idx, p.fingerprint).unwrap();
            for op in &p.ops {
                writeln!(
                    s,
                    "    {:<60} fp={:016x} mode={}",
                    op.op.render(),
                    op.fingerprint,
                    op.mode.label()
                )
                .unwrap();
            }
        }
        writeln!(
            s,
            "  {:<62} fp={:016x} mode={}",
            self.emit.op.render(),
            self.emit.fingerprint,
            self.emit.mode.label()
        )
        .unwrap();
        s
    }
}

/// What one replan changed: strategy transition, the affected pipeline
/// set, and a rendered before/after operator diff (the observable
/// `explain()` payoff the ROADMAP item asks for).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanDelta {
    /// Plan fingerprint before / after.
    pub from_fingerprint: u64,
    pub to_fingerprint: u64,
    /// Strategy before / after (may be equal on a filter-mode-only
    /// replan).
    pub from_strategy: Strategy,
    pub to_strategy: Strategy,
    /// Lane indices of pipelines whose operator chain changed.
    pub changed_lanes: Vec<usize>,
    /// Unified before/after diff of the changed operators.
    pub diff: String,
}

impl ReplanDelta {
    /// One-line summary: `cached-rewalk -> one-shot (3 pipelines)`.
    pub fn summary(&self) -> String {
        format!(
            "{} -> {} ({} pipeline{})",
            self.from_strategy.label(),
            self.to_strategy.label(),
            self.changed_lanes.len(),
            if self.changed_lanes.len() == 1 { "" } else { "s" },
        )
    }
}

/// Re-lower `plan` under `cfg` and diff the result against the
/// currently running `current` plan.
///
/// Returns `None` when the new config lowers to a fingerprint-identical
/// plan (nothing to change); otherwise the new plan plus a
/// [`ReplanDelta`] describing exactly which operators changed. The
/// *decision* to call this lives in [`super::cost::CostModel`]; the
/// state consequences (cache/IncBank migration or deliberate
/// invalidation) live with the caller that owns that state
/// ([`crate::engine::online::Engine`]).
pub fn replan(
    plan: &OptimizedPlan,
    current: &ExecPlan,
    cfg: &LowerConfig,
) -> Option<(ExecPlan, ReplanDelta)> {
    let next = lower(plan, cfg);
    if next.fingerprint == current.fingerprint {
        return None;
    }
    let mut changed_lanes = Vec::new();
    let mut diff = String::new();
    writeln!(
        diff,
        "replan {} -> {} fp {:016x} -> {:016x}",
        current.strategy.label(),
        next.strategy.label(),
        current.fingerprint,
        next.fingerprint
    )
    .unwrap();
    debug_assert_eq!(current.pipelines.len(), next.pipelines.len());
    for (old, new) in current.pipelines.iter().zip(&next.pipelines) {
        if old.fingerprint == new.fingerprint {
            continue;
        }
        changed_lanes.push(new.lane_idx);
        writeln!(diff, "  pipeline[{}]:", new.lane_idx).unwrap();
        // Operator chains may differ in length (WindowSlice appears
        // only under the delta strategy): render removed ops with `-`,
        // added with `+`, and skip positions that carry over unchanged
        // (same op + mode; fingerprints always differ downstream of the
        // first change because they chain).
        let mut o = old.ops.iter().peekable();
        let mut n = new.ops.iter().peekable();
        while o.peek().is_some() || n.peek().is_some() {
            match (o.peek(), n.peek()) {
                (Some(a), Some(b)) if a.op == b.op && a.mode == b.mode => {
                    o.next();
                    n.next();
                }
                (Some(a), Some(b)) if a.op.stage() == b.op.stage() => {
                    writeln!(diff, "    - {} mode={}", a.op.render(), a.mode.label()).unwrap();
                    writeln!(diff, "    + {} mode={}", b.op.render(), b.mode.label()).unwrap();
                    o.next();
                    n.next();
                }
                (Some(a), Some(b)) if (a.op.stage() as u8) < (b.op.stage() as u8) => {
                    writeln!(diff, "    - {} mode={}", a.op.render(), a.mode.label()).unwrap();
                    o.next();
                }
                (Some(_), Some(b)) => {
                    writeln!(diff, "    + {} mode={}", b.op.render(), b.mode.label()).unwrap();
                    n.next();
                }
                (Some(a), None) => {
                    writeln!(diff, "    - {} mode={}", a.op.render(), a.mode.label()).unwrap();
                    o.next();
                }
                (None, Some(b)) => {
                    writeln!(diff, "    + {} mode={}", b.op.render(), b.mode.label()).unwrap();
                    n.next();
                }
                (None, None) => unreachable!(),
            }
        }
    }
    if current.emit != next.emit {
        writeln!(diff, "  - {}", current.emit.op.render()).unwrap();
        writeln!(diff, "  + {}", next.emit.op.render()).unwrap();
    }
    let delta = ReplanDelta {
        from_fingerprint: current.fingerprint,
        to_fingerprint: next.fingerprint,
        from_strategy: current.strategy,
        to_strategy: next.strategy,
        changed_lanes,
        diff,
    };
    Some((next, delta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::compute::CompFunc;
    use crate::features::spec::{FeatureId, FeatureSpec, TimeRange};
    use crate::optimizer::fusion::fuse;

    fn spec(id: u32, types: Vec<u16>, mins: i64, comp: CompFunc) -> FeatureSpec {
        FeatureSpec {
            id: FeatureId(id),
            name: format!("f{id}"),
            event_types: types,
            window: TimeRange::mins(mins),
            attrs: vec![0, 2],
            comp,
        }
        .normalized()
    }

    fn sample() -> OptimizedPlan {
        fuse(
            &[
                spec(0, vec![1], 5, CompFunc::Count),
                spec(1, vec![1], 60, CompFunc::Sum),
                spec(2, vec![2], 5, CompFunc::Concat { max_len: 4 }),
                spec(3, vec![1, 2], 30, CompFunc::Concat { max_len: 4 }),
            ],
            true,
        )
    }

    fn cfg(cache: bool, inc: bool) -> LowerConfig {
        LowerConfig {
            enable_cache: cache,
            incremental_compute: inc,
            hierarchical_filter: true,
            projected_decode: true,
            batch_exec: true,
        }
    }

    #[test]
    fn strategy_selection_rules() {
        let plan = sample();
        assert_eq!(lower(&plan, &cfg(false, false)).strategy, Strategy::OneShot);
        // Incremental without cache degrades to OneShot (the engine
        // ignores the flag without a cache to define the delta).
        assert_eq!(lower(&plan, &cfg(false, true)).strategy, Strategy::OneShot);
        assert_eq!(
            lower(&plan, &cfg(true, false)).strategy,
            Strategy::CachedRewalk
        );
        assert_eq!(
            lower(&plan, &cfg(true, true)).strategy,
            Strategy::IncrementalDelta
        );
    }

    #[test]
    fn pipelines_mirror_lanes_and_stage_order() {
        let plan = sample();
        for c in [cfg(false, false), cfg(true, false), cfg(true, true)] {
            let exec = lower(&plan, &c);
            assert_eq!(exec.pipelines.len(), plan.lanes.len());
            for (p, lane) in exec.pipelines.iter().zip(&plan.lanes) {
                let stages: Vec<Stage> = p.ops.iter().map(|o| o.op.stage()).collect();
                let want = if exec.strategy == Strategy::IncrementalDelta {
                    vec![
                        Stage::Scan,
                        Stage::Project,
                        Stage::Filter,
                        Stage::WindowSlice,
                        Stage::Aggregate,
                    ]
                } else {
                    vec![Stage::Scan, Stage::Project, Stage::Filter, Stage::Aggregate]
                };
                assert_eq!(stages, want);
                let ExecOp::Scan {
                    event_type,
                    window_ms,
                    ..
                } = &p.ops[0].op
                else {
                    panic!("first op must be Scan")
                };
                assert_eq!(*event_type, lane.event_type);
                assert_eq!(*window_ms, lane.max_window.duration_ms);
            }
        }
    }

    #[test]
    fn delta_annotates_persistence_from_the_shared_predicate() {
        let plan = sample();
        let exec = lower(&plan, &cfg(true, true));
        for (spec, mode) in plan.features.iter().zip(&exec.agg_modes) {
            let want = if spec.requires_cross_lane_order() {
                AggMode::OneShot
            } else {
                AggMode::Persistent
            };
            assert_eq!(*mode, want, "{}", spec.name);
        }
        // Outside the delta strategy everything is one-shot.
        let exec = lower(&plan, &cfg(true, false));
        assert!(exec.agg_modes.iter().all(|m| *m == AggMode::OneShot));
    }

    #[test]
    fn fingerprints_are_stable_and_content_sensitive() {
        let plan = sample();
        let a = lower(&plan, &cfg(true, false));
        let b = lower(&plan, &cfg(true, false));
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.explain(), b.explain());
        // A different strategy re-fingerprints the plan.
        let c = lower(&plan, &cfg(true, true));
        assert_ne!(a.fingerprint, c.fingerprint);
        // A geometry change (one more feature) re-fingerprints too.
        let plan2 = fuse(
            &[
                spec(0, vec![1], 5, CompFunc::Count),
                spec(1, vec![1], 60, CompFunc::Sum),
                spec(2, vec![2], 5, CompFunc::Concat { max_len: 4 }),
                spec(3, vec![1, 2], 30, CompFunc::Concat { max_len: 4 }),
                spec(4, vec![1], 360, CompFunc::Mean),
            ],
            true,
        );
        let d = lower(&plan2, &cfg(true, false));
        assert_ne!(a.fingerprint, d.fingerprint);
        // Operators chain: two pipelines never share a fingerprint, and
        // ops within a pipeline are pairwise distinct.
        let mut seen: Vec<u64> = Vec::new();
        for p in &a.pipelines {
            for op in &p.ops {
                assert!(!seen.contains(&op.fingerprint));
                seen.push(op.fingerprint);
            }
        }
    }

    #[test]
    fn batch_mode_annotations_follow_the_scan_source() {
        let plan = sample();
        // Uncached one-shot: the whole pipeline runs at batch grain.
        let exec = lower(&plan, &cfg(false, false));
        for p in &exec.pipelines {
            assert!(p.ops.iter().all(|o| o.mode == ExecMode::Batch));
        }
        assert_eq!(exec.emit.mode, ExecMode::Batch);
        // Cache bridge: Scan/Project consume materialized cache rows
        // (row grain); Filter onward walks batches.
        for c in [cfg(true, false), cfg(true, true)] {
            let exec = lower(&plan, &c);
            for p in &exec.pipelines {
                for o in &p.ops {
                    let want = match o.op.stage() {
                        Stage::Scan | Stage::Project => ExecMode::RowWalk,
                        _ => ExecMode::Batch,
                    };
                    assert_eq!(o.mode, want, "{:?}", o.op.stage());
                }
            }
        }
        // Without batch_exec — or without the projection it needs —
        // every operator is the row-walk oracle.
        for c in [
            LowerConfig {
                batch_exec: false,
                ..cfg(false, false)
            },
            LowerConfig {
                projected_decode: false,
                ..cfg(false, false)
            },
        ] {
            let exec = lower(&plan, &c);
            assert!(exec
                .pipelines
                .iter()
                .flat_map(|p| &p.ops)
                .all(|o| o.mode == ExecMode::RowWalk));
            assert_eq!(exec.emit.mode, ExecMode::RowWalk);
        }
        assert!(!LowerConfig::baseline().batch_exec);
    }

    #[test]
    fn batch_toggle_re_fingerprints_and_renders() {
        let plan = sample();
        let a = lower(&plan, &cfg(false, false));
        let b = lower(
            &plan,
            &LowerConfig {
                batch_exec: false,
                ..cfg(false, false)
            },
        );
        assert_ne!(a.fingerprint, b.fingerprint);
        assert!(a.explain().contains("mode=batch"));
        assert!(!a.explain().contains("mode=row-walk"));
        assert!(b.explain().contains("mode=row-walk"));
        assert!(!b.explain().contains("mode=batch"));
        // The bridge plan renders both grains.
        let c = lower(&plan, &cfg(true, false));
        assert!(c.explain().contains("mode=row-walk"));
        assert!(c.explain().contains("mode=batch"));
    }

    #[test]
    fn member_attr_rewire_changes_the_fingerprint() {
        // A member reading different attrs while the lane's attr UNION
        // stays identical must still re-fingerprint the plan (the
        // Aggregate descriptor carries per-member attrs precisely so
        // the golden snapshots catch union-preserving rewires).
        let with_attrs = |f1_attrs: Vec<u16>| {
            let specs = vec![
                FeatureSpec {
                    id: FeatureId(0),
                    name: "f0".into(),
                    event_types: vec![1],
                    window: TimeRange::mins(5),
                    attrs: vec![0, 2],
                    comp: CompFunc::Count,
                }
                .normalized(),
                FeatureSpec {
                    id: FeatureId(1),
                    name: "f1".into(),
                    event_types: vec![1],
                    window: TimeRange::mins(5),
                    attrs: f1_attrs,
                    comp: CompFunc::Count,
                }
                .normalized(),
            ];
            lower(&fuse(&specs, true), &cfg(true, false))
        };
        let a = with_attrs(vec![0]);
        let b = with_attrs(vec![2]);
        // Same lane geometry and attr union ([0, 2]) either way…
        assert_eq!(a.pipelines.len(), b.pipelines.len());
        // …but the rewired member shows up in fingerprint and explain.
        assert_ne!(a.fingerprint, b.fingerprint);
        assert_ne!(a.explain(), b.explain());
    }

    #[test]
    fn explain_renders_every_operator() {
        let plan = sample();
        let exec = lower(&plan, &cfg(true, true));
        let text = exec.explain();
        assert!(text.starts_with("ExecPlan strategy=incremental-delta"));
        assert_eq!(
            text.matches("pipeline[").count(),
            plan.lanes.len(),
            "{text}"
        );
        for stage in ["Scan", "Project", "Filter", "WindowSlice", "Aggregate"] {
            assert_eq!(
                text.matches(&format!("    {stage}")).count(),
                plan.lanes.len(),
                "{stage} lines\n{text}"
            );
        }
        assert_eq!(text.matches("  Emit").count(), 1);
        // The baseline shape renders the full-decode Project.
        let base = lower(&fuse(&plan.features, false), &LowerConfig::baseline());
        assert_eq!(base.strategy, Strategy::OneShot);
        assert!(base.explain().contains("attrs=* (full decode)"));
    }

    #[test]
    fn lower_config_bits_roundtrip_and_strategy_rules() {
        for bits in 0..32u8 {
            let c = LowerConfig::from_bits(bits);
            assert_eq!(c.to_bits(), bits);
            assert_eq!(LowerConfig::from_bits(c.to_bits()), c);
        }
        assert_eq!(LowerConfig::baseline().strategy(), Strategy::OneShot);
        assert_eq!(cfg(true, false).strategy(), Strategy::CachedRewalk);
        assert_eq!(cfg(true, true).strategy(), Strategy::IncrementalDelta);
        // lower() and strategy() must agree forever.
        let plan = sample();
        for (cache, inc) in [(false, false), (true, false), (true, true)] {
            let c = cfg(cache, inc);
            assert_eq!(lower(&plan, &c).strategy, c.strategy());
        }
    }

    #[test]
    fn replan_is_none_for_identical_config() {
        let plan = sample();
        let c = cfg(true, false);
        let current = lower(&plan, &c);
        assert!(replan(&plan, &current, &c).is_none());
    }

    #[test]
    fn replan_diffs_strategy_and_filter_transitions() {
        let plan = sample();
        let current = lower(&plan, &cfg(true, false));

        // CachedRewalk -> OneShot: every pipeline's Scan source flips.
        let mut to = cfg(false, false);
        let (next, delta) = replan(&plan, &current, &to).unwrap();
        assert_eq!(next.strategy, Strategy::OneShot);
        assert_eq!(delta.from_strategy, Strategy::CachedRewalk);
        assert_eq!(delta.to_strategy, Strategy::OneShot);
        assert_eq!(delta.changed_lanes.len(), current.pipelines.len());
        assert_eq!(delta.from_fingerprint, current.fingerprint);
        assert_eq!(delta.to_fingerprint, next.fingerprint);
        assert!(delta.diff.contains("replan cached-rewalk -> one-shot"));
        assert!(delta.diff.contains("- Scan"), "{}", delta.diff);
        assert!(delta.diff.contains("+ Scan"), "{}", delta.diff);
        assert!(delta.summary().contains("cached-rewalk -> one-shot"));

        // Filter-mode-only replan: strategy unchanged, Filter ops diff.
        to = cfg(true, false);
        to.hierarchical_filter = false;
        let (next, delta) = replan(&plan, &current, &to).unwrap();
        assert_eq!(next.strategy, Strategy::CachedRewalk);
        assert_eq!(delta.from_strategy, delta.to_strategy);
        assert!(delta.diff.contains("- Filter"), "{}", delta.diff);
        assert!(delta.diff.contains("+ Filter"), "{}", delta.diff);
        assert!(!delta.diff.contains("- Scan"), "{}", delta.diff);

        // CachedRewalk -> IncrementalDelta: WindowSlice appears as a
        // pure insertion; Emit's persistent count changes.
        let (next, delta) = replan(&plan, &current, &cfg(true, true)).unwrap();
        assert_eq!(next.strategy, Strategy::IncrementalDelta);
        assert!(delta.diff.contains("+ WindowSlice"), "{}", delta.diff);
        assert!(!delta.diff.contains("- WindowSlice"), "{}", delta.diff);
    }
}
