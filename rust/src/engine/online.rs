//! The online execution phase (paper §3.1, Fig. 7 ❶–❹).
//!
//! Per inference request:
//! ❶ fetch previously computed intermediate results (decoded attribute
//!   rows) from the cache,
//! ❷ run `Retrieve`/`Decode` only for the missing interval of newly
//!   logged events,
//! ❸ feed cached + fresh rows through the (hierarchically) fused filter
//!   and assemble real-time feature values,
//! ❹ update the cache under the current memory budget via the greedy
//!   valuation policy.
//!
//! All of ❶–❹ live in the [`super::exec`] pipeline executor, driven by
//! the [`crate::optimizer::lower::ExecPlan`] IR lowered at compile time;
//! [`Engine`] is a thin per-session driver holding the mutable state
//! (cache, trigger watermarks, incremental state banks, the §5
//! staleness fast path) and scheduling the lowered pipelines.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::applog::codec::AttrCodec;
use crate::applog::event::{EventTypeId, TimestampMs};
use crate::applog::schema::Catalog;
use crate::applog::store::AppLogStore;
use crate::cache::store::CacheStore;
use crate::features::spec::FeatureSpec;
use crate::features::value::FeatureValue;
use crate::fegraph::node::OpBreakdown;
use crate::optimizer::cost::{CostConfig, CostModel, Observation, StrategySpace};
use crate::optimizer::lower::{self, ExecPlan, LowerConfig, ReplanDelta, Strategy};

use super::config::EngineConfig;
use super::exec::delta::IncBank;
use super::exec::pipeline;
use super::offline::{compile, CompiledEngine};
use super::Extractor;

/// Output of one online extraction.
#[derive(Debug, Clone)]
pub struct ExtractionResult {
    /// Feature values, in feature order.
    pub values: Vec<FeatureValue>,
    /// Per-operation breakdown (derived from the executor's
    /// per-operator counters).
    pub breakdown: OpBreakdown,
    /// End-to-end extraction wall time (ns).
    pub wall_ns: u64,
    /// Cache bytes held after the update step.
    pub cache_bytes: usize,
    /// Behavior types cached after the update step.
    pub cached_types: usize,
    /// Hierarchical-filter boundary comparisons (Fig. 11 metric).
    pub boundary_cmps: u64,
    /// Whether the values were served from the staleness fast path
    /// (§5 co-design mode) without re-extraction.
    pub served_stale: bool,
    /// App-log storage the method requires beyond the raw log (cloud
    /// baselines inflate this; AutoFeature keeps it 0).
    pub extra_storage_bytes: usize,
    /// The adaptive replan applied *after* this trigger, if any: the
    /// values above were still produced by the old plan; the next
    /// trigger runs the new one. `None` on non-adaptive engines.
    pub replan: Option<ReplanDelta>,
}

/// Per-session adaptive re-lowering state (`EngineConfig::adaptive_replan`).
///
/// The session's *active* plan is `exec` when present, else the shared
/// compiled plan. The overlay is an ordinary [`ExecPlan`] produced by
/// [`lower::replan`] from the same [`crate::optimizer::plan::OptimizedPlan`]
/// — replans only re-lower, they never re-fuse — so lane geometry,
/// fingerprint discipline and the explain format all carry over.
pub(crate) struct Adaptive {
    /// The active lowering configuration (starts at the compiled base).
    pub cfg: LowerConfig,
    /// The overlay plan; `None` while the active configuration is still
    /// the compiled base (the `Arc`-shared plan serves directly, and the
    /// overlay costs nothing).
    pub exec: Option<ExecPlan>,
    /// Windowed cost model fed from each trigger's counters.
    pub cost: CostModel,
    /// Replans applied over this session's lifetime (survives
    /// hibernation; the diff log below does not).
    pub replans: u64,
    /// Recent replan deltas, oldest first (observability only — capped,
    /// not serialized).
    pub log: Vec<ReplanDelta>,
}

/// Cap on the in-memory replan diff log.
const REPLAN_LOG_CAP: usize = 32;

impl Adaptive {
    pub(crate) fn new(cfg: &EngineConfig, compiled: &CompiledEngine) -> Adaptive {
        Adaptive {
            cfg: super::offline::lower_config(cfg),
            exec: None,
            cost: CostModel::new(
                CostConfig::default(),
                StrategySpace {
                    allow_incremental: cfg.incremental_compute,
                },
                compiled.span_ms(),
            ),
            replans: 0,
            log: Vec::new(),
        }
    }
}

/// The AutoFeature online engine.
///
/// Ownership is split for multi-session serving: the immutable
/// offline-compiled plan — including the lowered
/// [`crate::optimizer::lower::ExecPlan`] — lives in a shared
/// [`Arc<CompiledEngine>`] (compile once per deployed model, share
/// across every user session of the service — see
/// [`crate::coordinator::pool::SessionPool`]), while all per-session
/// mutable state (the [`CacheStore`], extraction watermarks, the
/// incremental state banks, the staleness fast path) stays inside this
/// lightweight per-user value.
pub struct Engine {
    cfg: EngineConfig,
    compiled: Arc<CompiledEngine>,
    codec: Box<dyn AttrCodec>,
    cache: CacheStore,
    last_now: Option<TimestampMs>,
    /// Previous extraction's values (kept only in co-design mode).
    last_values: Option<(TimestampMs, Vec<FeatureValue>)>,
    /// Persistent incremental state banks (delta-strategy plans).
    inc: Option<IncBank>,
    /// Adaptive re-lowering state (`cfg.adaptive_replan` only).
    adaptive: Option<Adaptive>,
}

impl Engine {
    /// Compile + instantiate in one step.
    pub fn new(
        features: Vec<FeatureSpec>,
        catalog: &Catalog,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        let compiled = compile(features, catalog, &cfg)?;
        Ok(Self::from_compiled(compiled, cfg))
    }

    /// Instantiate from a pre-compiled plan (offline phase output).
    pub fn from_compiled(compiled: CompiledEngine, cfg: EngineConfig) -> Engine {
        Self::from_shared(Arc::new(compiled), cfg)
    }

    /// Instantiate a per-session engine over a *shared* compiled plan.
    /// `cfg` must be the configuration the plan was compiled with
    /// (fusion, codec and the lowered execution strategy are baked into
    /// the plan).
    pub fn from_shared(compiled: Arc<CompiledEngine>, cfg: EngineConfig) -> Engine {
        Engine {
            codec: cfg.codec.build(),
            cache: CacheStore::new(cfg.cache_budget_bytes),
            adaptive: cfg.adaptive_replan.then(|| Adaptive::new(&cfg, &compiled)),
            cfg,
            compiled,
            last_now: None,
            last_values: None,
            inc: None,
        }
    }

    /// The plan this session actually runs: the per-session overlay when
    /// an adaptive replan has diverged from the compiled base, else the
    /// shared compiled plan.
    pub fn active_exec(&self) -> &ExecPlan {
        match &self.adaptive {
            Some(a) => a.exec.as_ref().unwrap_or(&self.compiled.exec),
            None => &self.compiled.exec,
        }
    }

    /// Replans applied over this session's lifetime (0 on non-adaptive
    /// engines). Survives hibernation.
    pub fn replans(&self) -> u64 {
        self.adaptive.as_ref().map_or(0, |a| a.replans)
    }

    /// Recent replan deltas, oldest first (adaptive engines only;
    /// in-memory observability, not serialized).
    pub fn replan_log(&self) -> &[ReplanDelta] {
        self.adaptive.as_ref().map_or(&[], |a| a.log.as_slice())
    }

    /// Render the adaptive view of this session: the compiled base plan,
    /// the cost model's current estimates, every replan diff applied so
    /// far, and the active overlay (when diverged). Static sessions get
    /// the plain [`CompiledEngine::explain`] plus a note.
    pub fn explain_adaptive(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "# base plan (compiled, Arc-shared)");
        s.push_str(&self.compiled.explain());
        let Some(a) = &self.adaptive else {
            s.push_str("\nadaptive: off (static session)\n");
            return s;
        };
        let (gap, fresh, window, sel) = a.cost.estimates();
        let _ = writeln!(s, "\n# cost model ({} observations)", a.cost.observations());
        let _ = writeln!(
            s,
            "est gap_ms={gap:.1} fresh_rows={fresh:.1} window_rows={window:.1} selectivity={sel:.3}"
        );
        let _ = writeln!(s, "replans={}", a.replans);
        for d in &a.log {
            let _ = writeln!(s, "\n# replan: {}", d.summary());
            s.push_str(&d.diff);
        }
        match &a.exec {
            Some(exec) => {
                let _ = writeln!(s, "\n# active plan (session overlay)");
                s.push_str(&exec.explain());
            }
            None => {
                let _ = writeln!(s, "\n# active plan = base (no divergence)");
            }
        }
        s
    }

    /// The compiled plan (inspection / reports).
    pub fn compiled(&self) -> &CompiledEngine {
        &self.compiled
    }

    /// A shareable handle to the compiled plan (spawn sibling sessions).
    pub fn shared_plan(&self) -> Arc<CompiledEngine> {
        Arc::clone(&self.compiled)
    }

    /// Current cache usage in bytes (Fig. 17b metric).
    pub fn cache_bytes(&self) -> usize {
        self.cache.used_bytes()
    }

    /// The cross-execution cache (inspection: tests assert the
    /// watermark-vs-log contract that the cache bridge only
    /// `debug_assert!`s on the hot path).
    pub fn cache(&self) -> &CacheStore {
        &self.cache
    }

    /// Whether persistent incremental state banks currently exist
    /// (inspection; delta-strategy sessions only).
    pub fn has_incremental_state(&self) -> bool {
        self.inc.is_some()
    }

    /// Dynamically adjust the cache budget (OS memory pressure). Evicts
    /// lowest-ratio types first if shrinking below current usage.
    pub fn set_cache_budget(&mut self, budget_bytes: usize, interval_ms: i64) {
        let compiled = &self.compiled;
        let prio = |t: EventTypeId| {
            let window = compiled.type_windows.get(&t).copied().unwrap_or(0);
            let overlap = if window <= 0 {
                0.0
            } else {
                ((window - interval_ms) as f64 / window as f64).max(0.0)
            };
            if compiled.profile.contains(t) {
                overlap * compiled.profile.stat(t).ratio()
            } else {
                0.0
            }
        };
        self.cache.set_budget(budget_bytes, prio);
    }

    /// The interval estimate used for valuation.
    fn interval_ms(&self, now: TimestampMs) -> i64 {
        match self.last_now {
            Some(last) if now > last => now - last,
            _ => self.cfg.expected_interval_ms,
        }
    }

    /// Apply a re-lowering decision: build the overlay plan from the
    /// shared compiled plan and migrate or deliberately invalidate the
    /// session state pinned to the outgoing one (DESIGN.md §Adaptive
    /// re-lowering has the full migration-vs-invalidation table).
    /// Returns the delta when the plan actually changed; no-op on
    /// non-adaptive engines. Also the deterministic test seam: the
    /// differential suite forces transitions through here without
    /// depending on cost-model dynamics.
    pub(crate) fn apply_replan(&mut self, next_cfg: LowerConfig) -> Option<ReplanDelta> {
        let adaptive = self.adaptive.as_mut()?;
        let active = adaptive.exec.as_ref().unwrap_or(&self.compiled.exec);
        let from = active.strategy;
        let (next_exec, delta) = match lower::replan(&self.compiled.plan, active, &next_cfg) {
            Some(x) => x,
            None => {
                // Identical lowering (defensive): adopt the config so
                // the cost model stops proposing it, count no replan.
                adaptive.cfg = next_cfg;
                return None;
            }
        };
        match (from, next_exec.strategy) {
            // Filter-mode flip within one strategy: cached rows carry
            // the full attr union, so they are valid under either
            // filter mode — pure migration, nothing to drop.
            (a, b) if a == b => {}
            // One-shot plans have no cache bridge: keeping lanes around
            // would hold memory against a plan that never reads them.
            // Deliberate invalidation.
            (_, Strategy::OneShot) => {
                self.cache.clear();
                self.inc = None;
            }
            // The cached window migrates as-is (watermark continuity
            // holds: lanes gate only on their own watermarks);
            // incremental banks are deltas over the delta plan's slice
            // discipline and are dropped.
            (_, Strategy::CachedRewalk) => {
                self.inc = None;
            }
            // Cache migrates; the IncBank is rebuilt lazily by the
            // delta executor on the next trigger (fresh bank → exact
            // full-rewalk rebuild).
            (_, Strategy::IncrementalDelta) => {}
        }
        adaptive.cfg = next_cfg;
        // Replanning back onto the compiled base drops the overlay —
        // the session serves from the shared plan again.
        adaptive.exec =
            (next_exec.fingerprint != self.compiled.exec.fingerprint).then_some(next_exec);
        adaptive.replans += 1;
        if adaptive.log.len() == REPLAN_LOG_CAP {
            adaptive.log.remove(0);
        }
        adaptive.log.push(delta.clone());
        Some(delta)
    }

    /// Serialize all session-private mutable state — cached lanes with
    /// their watermarks, the incremental state bank, the staleness
    /// fast-path clock — into a versioned, CRC-checked blob (see
    /// [`super::state`]). The blob pins the compiled plan's fingerprint;
    /// the engine itself stays usable (export is non-destructive).
    /// Exporting the same state twice yields identical bytes.
    pub fn export_state(&self) -> Vec<u8> {
        super::state::encode(
            &self.compiled,
            &self.cache,
            self.last_now,
            &self.last_values,
            &self.inc,
            &self.adaptive,
        )
    }

    /// Rehydrate from an [`export_state`](Self::export_state) blob,
    /// replacing this session's mutable state wholesale. Fails (leaving
    /// the current state untouched) on any corruption, version mismatch,
    /// or plan-fingerprint mismatch. On success the session continues
    /// exactly where the exported one stopped: watermark continuity
    /// makes the next delta extraction replay zero rows.
    pub fn import_state(&mut self, data: &[u8]) -> Result<()> {
        let st = super::state::decode(&self.compiled, self.cache.budget(), data)?;
        match (self.cfg.adaptive_replan, st.adaptive) {
            (false, None) => {}
            (false, Some(_)) => {
                anyhow::bail!("adaptive session state offered to a non-adaptive engine")
            }
            // Static or legacy blob into an adaptive engine: resume on
            // the compiled base with a cold cost model (the blob pinned
            // the base fingerprint, so the plan itself is compatible).
            (true, None) => self.adaptive = Some(Adaptive::new(&self.cfg, &self.compiled)),
            (true, Some(sa)) => {
                ensure!(
                    sa.cost.space().allow_incremental == self.cfg.incremental_compute,
                    "adaptive session state was hibernated under a different strategy space"
                );
                self.adaptive = Some(sa);
            }
        }
        self.cache = st.cache;
        self.last_now = st.last_now;
        self.last_values = st.last_values;
        self.inc = st.inc;
        // Re-establish the budget invariant under this session's current
        // (possibly shrunken) grant: evicts lowest-priority lanes if the
        // restored state no longer fits.
        self.set_cache_budget(self.cache.budget(), self.cfg.expected_interval_ms);
        Ok(())
    }

    /// [`Extractor::extract`] with an optional cross-session decode
    /// cache. The fleet coordinator passes one
    /// [`SharedDecodeCache`](crate::applog::arena::SharedDecodeCache)
    /// per fused trigger group so payloads shared between co-located
    /// sessions (via the host-global payload arena) decode once per
    /// group. With `shared == None` this is exactly `extract` — the
    /// cache changes only *where* a projection is decoded, never its
    /// value, so results stay bit-identical either way.
    pub fn extract_shared(
        &mut self,
        store: &AppLogStore,
        now: TimestampMs,
        shared: Option<&crate::applog::arena::SharedDecodeCache>,
    ) -> Result<ExtractionResult> {
        if let Some(last) = self.last_now {
            ensure!(now >= last, "extraction times must be monotonic");
        }
        // §5 co-design fast path: serve bounded-staleness values.
        if self.cfg.staleness_ttl_ms > 0 {
            if let Some((t, values)) = &self.last_values {
                if now - *t <= self.cfg.staleness_ttl_ms {
                    let wall = Instant::now();
                    let values = values.clone();
                    // A stale serve is still an extraction: advance the
                    // trigger clock so (a) the next real extraction's
                    // interval estimate — which drives cache valuation
                    // and the arbiter's overlap priority — measures the
                    // true inter-extraction gap, not the distance to the
                    // pre-stale trigger, and (b) the monotonicity
                    // `ensure!` above also guards against triggers that
                    // jump behind a served-stale one.
                    self.last_now = Some(now);
                    return Ok(ExtractionResult {
                        values,
                        breakdown: OpBreakdown::default(),
                        wall_ns: wall.elapsed().as_nanos() as u64,
                        cache_bytes: self.cache.used_bytes(),
                        cached_types: self.cache.num_types(),
                        boundary_cmps: 0,
                        served_stale: true,
                        extra_storage_bytes: 0,
                        replan: None,
                    });
                }
            }
        }
        // Schedule the lowered pipelines — strategy dispatch, lane
        // walks, cache bridging and per-operator metering all live in
        // the executor.
        let wall = Instant::now();
        let interval_ms = self.interval_ms(now);
        // The trigger gap feeds the cost model *before* the clock
        // advances (0 on the first trigger: no gap to observe).
        let gap_ms = match self.last_now {
            Some(last) => now - last,
            None => 0,
        };
        let exec = match &self.adaptive {
            Some(a) => a.exec.as_ref().unwrap_or(&self.compiled.exec),
            None => &self.compiled.exec,
        };
        let out = pipeline::execute(
            &self.compiled,
            exec,
            self.codec.as_ref(),
            self.cfg.policy,
            &mut self.cache,
            &mut self.inc,
            store,
            now,
            interval_ms,
            shared,
        )?;

        self.last_now = Some(now);
        if self.cfg.staleness_ttl_ms > 0 {
            self.last_values = Some((now, out.values.clone()));
        }
        let mut breakdown = out.counters.breakdown();
        let mut replan = None;
        let mut due = None;
        if let Some(adaptive) = &mut self.adaptive {
            let filter = out.counters.stage(crate::optimizer::lower::Stage::Filter);
            adaptive.cost.observe(&Observation {
                gap_ms,
                fresh_rows: breakdown.rows_retrieved,
                window_rows: breakdown.rows_from_cache + breakdown.rows_retrieved,
                filter_rows_in: filter.rows_in,
                filter_rows_out: filter.rows_out,
                extract_ns: wall.elapsed().as_nanos() as u64,
            });
            due = adaptive.cost.maybe_replan(&adaptive.cfg);
        }
        if let Some(next_cfg) = due {
            let t0 = Instant::now();
            replan = self.apply_replan(next_cfg);
            if replan.is_some() {
                breakdown.replans = 1;
                breakdown.replan_ns = t0.elapsed().as_nanos() as u64;
            }
        }
        Ok(ExtractionResult {
            values: out.values,
            breakdown,
            wall_ns: wall.elapsed().as_nanos() as u64,
            cache_bytes: self.cache.used_bytes(),
            cached_types: self.cache.num_types(),
            boundary_cmps: out.boundary_cmps,
            served_stale: false,
            extra_storage_bytes: 0,
            replan,
        })
    }
}

impl Extractor for Engine {
    fn extract(&mut self, store: &AppLogStore, now: TimestampMs) -> Result<ExtractionResult> {
        self.extract_shared(store, now, None)
    }

    fn label(&self) -> &'static str {
        match (self.cfg.enable_fusion, self.cfg.enable_cache) {
            (true, true) if self.cfg.incremental_compute => "AutoFeature+Δ",
            (true, true) => "AutoFeature",
            (true, false) => "w/ Fusion",
            (false, true) => "w/ Cache",
            (false, false) => "engine-naive",
        }
    }

    fn reset(&mut self) {
        self.cache.clear();
        self.last_now = None;
        self.last_values = None;
        // Incremental states are deltas *over the cache* — they die
        // with it.
        self.inc = None;
        // A reset session observed nothing: drop the overlay back to the
        // compiled base and start the cost model cold.
        if self.adaptive.is_some() {
            self.adaptive = Some(Adaptive::new(&self.cfg, &self.compiled));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::naive::NaiveExtractor;
    use crate::engine::exec::testutil::setup;

    fn extract_with(
        cfg: EngineConfig,
        specs: &[FeatureSpec],
        cat: &Catalog,
        store: &AppLogStore,
        nows: &[i64],
    ) -> Vec<Vec<FeatureValue>> {
        let mut eng = Engine::new(specs.to_vec(), cat, cfg).unwrap();
        nows.iter()
            .map(|&now| eng.extract(store, now).unwrap().values)
            .collect()
    }

    // Helper shim: NaiveExtractor takes a CodecKind.
    #[allow(non_snake_case)]
    fn CodecKindForTest() -> crate::applog::codec::CodecKind {
        crate::applog::codec::CodecKind::Jsonish
    }

    #[test]
    fn all_configs_agree_with_naive_baseline() {
        let (cat, specs, store) = setup();
        let nows = [10 * 60_000i64, 20 * 60_000, 21 * 60_000, 40 * 60_000];
        let mut naive = NaiveExtractor::new(specs.clone(), CodecKindForTest());
        let expected: Vec<Vec<FeatureValue>> = nows
            .iter()
            .map(|&now| naive.extract(&store, now).unwrap().values)
            .collect();
        for cfg in [
            EngineConfig::autofeature(),
            EngineConfig::fusion_only(),
            EngineConfig::cache_only(),
            EngineConfig::naive(),
            EngineConfig {
                hierarchical_filter: false,
                ..EngineConfig::autofeature()
            },
            EngineConfig::incremental(),
            EngineConfig {
                enable_fusion: false,
                ..EngineConfig::incremental()
            },
            EngineConfig::adaptive(),
        ] {
            let got = extract_with(cfg, &specs, &cat, &store, &nows);
            for (step, (g, e)) in got.iter().zip(&expected).enumerate() {
                for (i, (a, b)) in g.iter().zip(e).enumerate() {
                    assert!(
                        a.approx_eq(b, 1e-9),
                        "cfg fusion={} cache={} step {step} feature {i}: {a:?} vs {b:?}",
                        cfg.enable_fusion,
                        cfg.enable_cache,
                    );
                }
            }
        }
    }

    #[test]
    fn staleness_mode_serves_bounded_stale_values() {
        let (cat, specs, store) = setup();
        let mut eng = Engine::new(specs, &cat, EngineConfig::stale_tolerant(60_000)).unwrap();
        let r1 = eng.extract(&store, 30 * 60_000).unwrap();
        assert!(!r1.served_stale);
        // Within the TTL: same values, no work.
        let r2 = eng.extract(&store, 30 * 60_000 + 30_000).unwrap();
        assert!(r2.served_stale);
        assert_eq!(r2.values, r1.values);
        assert_eq!(r2.breakdown.rows_decoded, 0);
        // Beyond the TTL: fresh extraction again.
        let r3 = eng.extract(&store, 32 * 60_000).unwrap();
        assert!(!r3.served_stale);
    }

    #[test]
    fn stale_serve_advances_the_trigger_clock() {
        // Regression (§5 fast path): serving stale values used to return
        // without touching `last_now`, so the next real extraction's
        // interval estimate — the dynamic term of the cache valuation —
        // measured from the pre-stale trigger, and non-monotonic
        // triggers behind a stale serve slipped past the `ensure!`.
        let (cat, specs, store) = setup();
        let mut eng = Engine::new(specs, &cat, EngineConfig::stale_tolerant(60_000)).unwrap();
        let t1 = 30 * 60_000i64;
        let r1 = eng.extract(&store, t1).unwrap();
        assert!(!r1.served_stale);
        let t2 = t1 + 30_000;
        let r2 = eng.extract(&store, t2).unwrap();
        assert!(r2.served_stale);
        // The stale serve is an extraction: the clock advanced.
        assert_eq!(eng.last_now, Some(t2));
        // Valuation sees the true inter-extraction interval (t3 - t2,
        // not t3 - t1).
        let t3 = t1 + 90_000;
        assert_eq!(eng.interval_ms(t3), t3 - t2);
        // And monotonicity is enforced against the served trigger too.
        assert!(eng.extract(&store, t2 - 10_000).is_err());
        let r3 = eng.extract(&store, t3).unwrap();
        assert!(!r3.served_stale);
    }

    #[test]
    fn staleness_disabled_by_default() {
        let (cat, specs, store) = setup();
        let mut eng = Engine::new(specs, &cat, EngineConfig::autofeature()).unwrap();
        eng.extract(&store, 30 * 60_000).unwrap();
        let r = eng.extract(&store, 30 * 60_000 + 1).unwrap();
        assert!(!r.served_stale);
    }

    #[test]
    fn fusion_label_mapping() {
        let (cat, specs, _) = setup();
        let eng = Engine::new(specs, &cat, EngineConfig::autofeature()).unwrap();
        assert_eq!(eng.label(), "AutoFeature");
    }

    #[test]
    fn export_import_roundtrips_mid_stream() {
        // Hibernate after the second trigger, rehydrate into a fresh
        // sibling over the same shared plan, and continue both: values,
        // cache footprint and incremental state must stay identical.
        let (cat, specs, store) = setup();
        for cfg in [
            EngineConfig::autofeature(),
            EngineConfig::incremental(),
            EngineConfig::fusion_only(),
            EngineConfig::stale_tolerant(60_000),
            EngineConfig::adaptive(),
        ] {
            let compiled = std::sync::Arc::new(
                crate::engine::offline::compile(specs.clone(), &cat, &cfg).unwrap(),
            );
            let mut a = Engine::from_shared(std::sync::Arc::clone(&compiled), cfg);
            a.extract(&store, 20 * 60_000).unwrap();
            a.extract(&store, 21 * 60_000).unwrap();
            let blob = a.export_state();
            // Determinism: exporting unchanged state twice is byte-equal.
            assert_eq!(blob, a.export_state());
            let mut b = Engine::from_shared(std::sync::Arc::clone(&compiled), cfg);
            b.import_state(&blob).unwrap();
            assert_eq!(a.cache_bytes(), b.cache_bytes());
            assert_eq!(a.has_incremental_state(), b.has_incremental_state());
            for now in [22 * 60_000i64, 25 * 60_000, 40 * 60_000] {
                let ra = a.extract(&store, now).unwrap();
                let rb = b.extract(&store, now).unwrap();
                assert_eq!(ra.values, rb.values, "diverged @ {now}");
                assert_eq!(ra.cache_bytes, rb.cache_bytes, "cache drift @ {now}");
                assert_eq!(ra.served_stale, rb.served_stale);
            }
        }
    }

    #[test]
    fn import_rejects_corruption_and_foreign_plans() {
        let (cat, specs, store) = setup();
        let cfg = EngineConfig::incremental();
        let mut eng = Engine::new(specs.clone(), &cat, cfg).unwrap();
        eng.extract(&store, 20 * 60_000).unwrap();
        let blob = eng.export_state();
        // Any single-byte corruption is caught by the CRC (or the
        // header checks for the length/magic bytes).
        let mut bad = blob.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x04;
        assert!(eng.import_state(&bad).is_err());
        assert!(eng.import_state(&blob[..blob.len() - 1]).is_err());
        // A plan with different features must refuse the blob.
        let mut other = Engine::new(specs[..specs.len() - 1].to_vec(), &cat, cfg).unwrap();
        assert!(other.import_state(&blob).is_err());
        // The original still imports cleanly.
        assert!(eng.import_state(&blob).is_ok());
    }

    #[test]
    fn legacy_v1_state_blob_still_imports() {
        use crate::applog::blockcodec::{self, BlockCodec};
        use crate::util::wire;
        let (cat, specs, store) = setup();
        let cfg = EngineConfig::incremental();
        let mut eng = Engine::new(specs.clone(), &cat, cfg).unwrap();
        eng.extract(&store, 20 * 60_000).unwrap();
        eng.extract(&store, 21 * 60_000).unwrap();
        let v2 = eng.export_state();
        // Down-convert by hand to the retired v1 layout: same payload,
        // uncompressed, directly after the blob_len header.
        let body = &v2[..v2.len() - 4];
        let hp = &mut 10usize;
        let codec = BlockCodec::from_tag(wire::get_u8(body, hp).unwrap()).unwrap();
        let raw_len = wire::get_varint(body, hp).unwrap() as usize;
        let payload = blockcodec::decompress(codec, &body[*hp..], raw_len).unwrap();
        // v2 must actually shrink this cache-heavy payload.
        assert!(v2.len() < payload.len() + 14, "codec probe failed to shrink state");
        let mut v1 = Vec::new();
        v1.extend_from_slice(b"AFSS");
        v1.extend_from_slice(&1u16.to_le_bytes());
        v1.extend_from_slice(&((payload.len() + 14) as u32).to_le_bytes());
        v1.extend_from_slice(&payload);
        let crc = wire::crc32(&v1);
        v1.extend_from_slice(&crc.to_le_bytes());
        let mut revived = Engine::new(specs, &cat, cfg).unwrap();
        revived.import_state(&v1).unwrap();
        assert_eq!(revived.cache_bytes(), eng.cache_bytes());
        let now = 22 * 60_000i64;
        assert_eq!(
            revived.extract(&store, now).unwrap().values,
            eng.extract(&store, now).unwrap().values
        );
    }

    #[test]
    fn forced_replans_are_value_transparent() {
        // The differential invariant of the adaptive loop, in its
        // deterministic form: drive every strategy/filter transition
        // through `apply_replan` and hold the session's values exactly
        // equal to a never-replanned twin's at every trigger.
        let (cat, specs, store) = setup();
        let cfg = EngineConfig::adaptive();
        let base = crate::engine::offline::lower_config(&cfg);
        let mut adap = Engine::new(specs.clone(), &cat, cfg).unwrap();
        let mut twin = Engine::new(specs, &cat, EngineConfig::autofeature()).unwrap();
        let nows = [20, 21, 22, 25, 30, 31, 32, 40].map(|m| m * 60_000i64);
        for (i, &now) in nows.iter().enumerate() {
            let ra = adap.extract(&store, now).unwrap();
            let rt = twin.extract(&store, now).unwrap();
            assert_eq!(ra.values, rt.values, "diverged at step {i}");
            match i {
                1 => {
                    // cached-rewalk -> one-shot: deliberate invalidation.
                    let d = adap
                        .apply_replan(LowerConfig {
                            enable_cache: false,
                            ..base
                        })
                        .expect("replan to one-shot");
                    assert_eq!(d.to_strategy, Strategy::OneShot);
                    assert_eq!(adap.cache_bytes(), 0, "one-shot invalidates the cache");
                    assert!(!adap.has_incremental_state());
                }
                3 => {
                    // one-shot -> cached-rewalk: back onto the shared
                    // base plan, overlay dropped.
                    let d = adap.apply_replan(base).expect("replan back to cached");
                    assert_eq!(d.to_strategy, Strategy::CachedRewalk);
                    assert_eq!(
                        adap.active_exec().fingerprint,
                        adap.compiled().exec.fingerprint,
                        "returning to the base config must drop the overlay"
                    );
                }
                5 => {
                    // Filter-mode flip: same strategy, cache migrates.
                    assert!(adap.cache_bytes() > 0);
                    let d = adap
                        .apply_replan(LowerConfig {
                            hierarchical_filter: false,
                            ..base
                        })
                        .expect("filter flip");
                    assert_eq!(d.from_strategy, d.to_strategy);
                    assert!(adap.cache_bytes() > 0, "filter flip migrates the cache");
                }
                _ => {}
            }
        }
        assert_eq!(adap.replans(), 3);
        assert_eq!(adap.replan_log().len(), 3);
        let text = adap.explain_adaptive();
        assert!(text.contains("# base plan"), "{text}");
        assert!(text.contains("replans=3"), "{text}");
        assert!(text.contains("# active plan (session overlay)"), "{text}");
        // Reset drops the overlay and starts the cost model cold.
        adap.reset();
        assert_eq!(adap.replans(), 0);
        assert_eq!(
            adap.active_exec().fingerprint,
            adap.compiled().exec.fingerprint
        );
    }

    #[test]
    fn adaptive_state_survives_hibernation() {
        let (cat, specs, store) = setup();
        let cfg = EngineConfig::adaptive();
        let base = crate::engine::offline::lower_config(&cfg);
        let compiled = std::sync::Arc::new(
            crate::engine::offline::compile(specs.clone(), &cat, &cfg).unwrap(),
        );
        let mut a = Engine::from_shared(std::sync::Arc::clone(&compiled), cfg);
        a.extract(&store, 20 * 60_000).unwrap();
        a.extract(&store, 21 * 60_000).unwrap();
        a.apply_replan(LowerConfig {
            hierarchical_filter: false,
            ..base
        })
        .expect("forced filter flip");
        a.extract(&store, 22 * 60_000).unwrap();
        let blob = a.export_state();
        assert_eq!(blob, a.export_state(), "export must be deterministic");
        let mut b = Engine::from_shared(std::sync::Arc::clone(&compiled), cfg);
        b.import_state(&blob).unwrap();
        // The replan tally, the overlay plan and the pre-sleep cost
        // model all cross hibernation.
        assert_eq!(b.replans(), 1);
        assert_eq!(b.active_exec().fingerprint, a.active_exec().fingerprint);
        assert_ne!(b.active_exec().fingerprint, compiled.exec.fingerprint);
        assert_eq!(
            b.adaptive.as_ref().unwrap().cost,
            a.adaptive.as_ref().unwrap().cost,
            "post-wake cost model must resume from pre-sleep statistics"
        );
        for now in [23 * 60_000i64, 25 * 60_000, 40 * 60_000] {
            assert_eq!(
                a.extract(&store, now).unwrap().values,
                b.extract(&store, now).unwrap().values,
                "diverged @ {now}"
            );
        }
        // An adaptive blob must not rehydrate a non-adaptive session...
        let mut plain =
            Engine::from_shared(std::sync::Arc::clone(&compiled), EngineConfig::autofeature());
        plain.extract(&store, 20 * 60_000).unwrap();
        assert!(plain.import_state(&blob).is_err());
        // ...while a static blob into an adaptive session resumes on the
        // compiled base with a cold model.
        let static_blob = plain.export_state();
        let mut c = Engine::from_shared(std::sync::Arc::clone(&compiled), cfg);
        c.import_state(&static_blob).unwrap();
        assert_eq!(c.replans(), 0);
        assert_eq!(c.active_exec().fingerprint, compiled.exec.fingerprint);
    }

    #[test]
    fn sessions_share_one_compiled_plan() {
        // The plan/state split: one offline compile, many independent
        // per-session engines over the same Arc'd plan, each with its
        // own cache and watermarks, all extracting identical values.
        let (cat, specs, store) = setup();
        let cfg = EngineConfig::autofeature();
        let compiled = std::sync::Arc::new(
            crate::engine::offline::compile(specs.clone(), &cat, &cfg).unwrap(),
        );
        let mut a = Engine::from_shared(std::sync::Arc::clone(&compiled), cfg);
        let mut b = Engine::from_shared(std::sync::Arc::clone(&compiled), cfg);
        assert!(std::sync::Arc::ptr_eq(&a.shared_plan(), &b.shared_plan()));

        let mut naive = NaiveExtractor::new(specs, CodecKindForTest());
        for now in [20 * 60_000i64, 22 * 60_000, 40 * 60_000] {
            let want = naive.extract(&store, now).unwrap().values;
            for eng in [&mut a, &mut b] {
                let got = eng.extract(&store, now).unwrap().values;
                for (x, y) in got.iter().zip(&want) {
                    assert!(x.approx_eq(y, 1e-9), "{x:?} vs {y:?} @ {now}");
                }
            }
        }
        // Per-session state stays independent: resetting one session
        // must not touch its sibling's cache.
        assert!(a.cache_bytes() > 0 && b.cache_bytes() > 0);
        a.reset();
        assert_eq!(a.cache_bytes(), 0);
        assert!(b.cache_bytes() > 0);
    }
}
