"""Pallas FM kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fm_kernel import fm_interaction
from compile.kernels.ref import fm_interaction_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 9),
    n=st.integers(1, 48),
    d=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_random_shapes(b, n, d, seed):
    x = _rand(seed, (b, n))
    v = _rand(seed + 1, (n, d))
    got = fm_interaction(x, v)
    want = fm_interaction_ref(x, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    block_b=st.sampled_from([1, 2, 8, 16]),
    block_n=st.sampled_from([8, 16, 128, 256]),
)
def test_block_shape_invariance(block_b, block_n):
    """Tiling parameters must never change the numerics."""
    x = _rand(3, (5, 37))
    v = _rand(4, (37, 11))
    got = fm_interaction(x, v, block_b=block_b, block_n=block_n)
    want = fm_interaction_ref(x, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_zero_input_gives_zero():
    x = jnp.zeros((4, 20), jnp.float32)
    v = _rand(0, (20, 8))
    np.testing.assert_array_equal(np.asarray(fm_interaction(x, v)), 0.0)


def test_single_field_is_zero_interaction():
    """One field has no pairwise partner: interaction must be exactly 0."""
    x = _rand(1, (3, 1))
    v = _rand(2, (1, 6))
    np.testing.assert_allclose(np.asarray(fm_interaction(x, v)), 0.0, atol=1e-6)


def test_two_fields_closed_form():
    """n=2: out_d must equal v_0d * v_1d * x_0 * x_1 exactly."""
    x = jnp.array([[2.0, 3.0]], jnp.float32)
    v = jnp.array([[1.0, -1.0], [0.5, 2.0]], jnp.float32)
    want = (v[0] * v[1] * 6.0)[None, :]
    np.testing.assert_allclose(np.asarray(fm_interaction(x, v)), np.asarray(want), rtol=1e-5)


def test_large_values_stable():
    x = 100.0 * _rand(9, (2, 16))
    v = _rand(10, (16, 8))
    got = fm_interaction(x, v)
    want = fm_interaction_ref(x, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3)
    assert np.all(np.isfinite(np.asarray(got)))


def test_scale_quadratically():
    """FM interactions are 2-homogeneous: f(a*x) = a^2 * f(x)."""
    x = _rand(11, (3, 12))
    v = _rand(12, (12, 5))
    one = np.asarray(fm_interaction(x, v))
    three = np.asarray(fm_interaction(3.0 * x, v))
    np.testing.assert_allclose(three, 9.0 * one, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [1, 7, 8, 127, 128, 129])
def test_padding_boundaries(n):
    """Field counts at/around the tile boundary."""
    x = _rand(20 + n, (2, n))
    v = _rand(21 + n, (n, 4))
    got = fm_interaction(x, v)
    want = fm_interaction_ref(x, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
