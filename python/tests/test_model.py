"""Model-level (L2) tests: shapes, determinism, kernel-vs-ref inside the
full graph, and per-service configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    SERVICE_CONFIGS,
    ModelConfig,
    example_inputs,
    forward,
    init_params,
    make_inference_fn,
)

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", sorted(SERVICE_CONFIGS))
def test_forward_scalar_in_unit_interval(name):
    cfg = SERVICE_CONFIGS[name]
    params = init_params(cfg)
    out = forward(params, *example_inputs(cfg))
    assert out.shape == ()
    assert 0.0 < float(out) < 1.0


@pytest.mark.parametrize("name", sorted(SERVICE_CONFIGS))
def test_pallas_path_matches_ref_path(name):
    """Kernels validated *inside* the full model graph."""
    cfg = SERVICE_CONFIGS[name]
    params = init_params(cfg)
    inputs = example_inputs(cfg)
    got = forward(params, *inputs, use_ref=False)
    want = forward(params, *inputs, use_ref=True)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-5)


def test_deterministic_params():
    cfg = SERVICE_CONFIGS["sr"]
    a, b = init_params(cfg), init_params(cfg)
    for key in a:
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(b[key]))


def test_different_seeds_different_params():
    a = init_params(ModelConfig(name="x", n_user=10, seed=1))
    b = init_params(ModelConfig(name="x", n_user=10, seed=2))
    assert not np.allclose(np.asarray(a["fm_v"]), np.asarray(b["fm_v"]))


def test_inference_fn_is_jittable_and_deterministic():
    cfg = SERVICE_CONFIGS["kp"]
    fn = jax.jit(make_inference_fn(cfg))
    inputs = example_inputs(cfg)
    (a,) = fn(*inputs)
    (b,) = fn(*inputs)
    assert float(a) == float(b)


def test_mask_changes_prediction():
    """The sequence mask must actually gate the sequence contribution."""
    cfg = SERVICE_CONFIGS["cp"]
    params = init_params(cfg)
    stat, seq, mask, cloud = example_inputs(cfg)
    full = forward(params, stat, seq, jnp.ones_like(mask), cloud)
    none = forward(params, stat, seq, jnp.zeros_like(mask), cloud)
    assert abs(float(full) - float(none)) > 1e-6


def test_stat_features_change_prediction():
    cfg = SERVICE_CONFIGS["vr"]
    params = init_params(cfg)
    stat, seq, mask, cloud = example_inputs(cfg)
    base = forward(params, stat, seq, mask, cloud)
    bumped = forward(params, stat + 1.0, seq, mask, cloud)
    assert abs(float(base) - float(bumped)) > 1e-7


@pytest.mark.parametrize("name", sorted(SERVICE_CONFIGS))
def test_service_dims_match_paper(name):
    """Fig. 12a feature counts."""
    expected = {"cp": 86, "kp": 53, "sr": 40, "pr": 103, "vr": 134}
    assert SERVICE_CONFIGS[name].n_user == expected[name]
