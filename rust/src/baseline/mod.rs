//! Comparison systems.
//!
//! * [`naive`] — the industry-standard *w/o AutoFeature* pipeline: every
//!   feature extracts independently (direct FE-graph execution).
//! * [`decoded_log`] — cloud-side baseline 1 (Table 1): `Decode` is
//!   offloaded to logging time; the device keeps a wide-column decoded
//!   mirror of the app log (one column per unique attribute).
//! * [`feature_store`] — cloud-side baseline 2 (Table 1): `Decode` and
//!   `Retrieve` are offloaded; the device keeps one pre-filtered row per
//!   (behavior event × requiring feature).
//! * [`storage`] — storage-accounting helpers behind Fig. 18(b): both
//!   cloud baselines trade latency for a 2.5–3× app-log inflation, which
//!   is what makes them impractical on-device.

pub mod decoded_log;
pub mod feature_store;
pub mod naive;
pub mod storage;
