//! The `Retrieve` query path (paper §3.2, operation 1).
//!
//! Mirrors the SQL the paper shows in footnote 2:
//! `SELECT * FROM applog WHERE event_name IN {event_names} AND
//! timestamp > {current_time - time_range}`.
//!
//! Three strategies are provided:
//! * [`retrieve`] — the indexed path over the segmented store: each
//!   sealed segment is tested against its **zone map** (min/max
//!   timestamp, type-occupancy bitmap) and skipped wholesale when it
//!   cannot contribute; surviving segments binary-search their per-type
//!   position lists, and the tail is merged last. Output order is global
//!   chronological (= position/seq order), exactly as the flat store
//!   produced.
//! * [`retrieve_project`] — `Retrieve` fused with a segment-granular
//!   `Decode`: rows that survive pruning are decoded straight into the
//!   requested attr projection from the de-duplicated payload arena
//!   (duplicate payloads within a segment decode once), never
//!   materializing an owned event row.
//! * [`retrieve_scan`] — a full-table linear scan, the reference oracle
//!   used by tests to validate the indexed paths.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use super::codec::AttrCodec;
use super::event::{AttrId, AttrValue, BehaviorEvent, EventTypeId, TimestampMs};
use super::segment::Segment;
use super::store::AppLogStore;

/// Inclusive-exclusive time window `[start, end)` over event timestamps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimeWindow {
    /// Window start (inclusive).
    pub start_ms: TimestampMs,
    /// Window end (exclusive).
    pub end_ms: TimestampMs,
}

impl TimeWindow {
    /// The paper's `timestamp > now - time_range` window, i.e.
    /// `[now - range, now)` with `end` exclusive (events logged at the
    /// trigger instant belong to the *next* execution).
    ///
    /// The start is clamped to the log epoch (t = 0): at session start a
    /// feature window can exceed the whole log history, and a negative
    /// `start_ms` would leak into downstream state such as cache
    /// watermarks ([`crate::cache::entry::CachedLane`]).
    pub fn last(now: TimestampMs, range_ms: i64) -> Self {
        TimeWindow {
            start_ms: (now - range_ms).max(0),
            end_ms: now,
        }
    }

    /// Whether a timestamp falls inside the window.
    #[inline]
    pub fn contains(&self, ts: TimestampMs) -> bool {
        ts >= self.start_ms && ts < self.end_ms
    }
}

/// Matching row positions of one segment, per queried type, merged back
/// into position (= chronological + seq) order. Returns the number of
/// positions pushed. The zone map is consulted first: a segment whose
/// `[min_ts, max_ts]` misses the window or whose bitmap holds none of
/// the queried types contributes nothing and is never row-scanned.
fn segment_positions(seg: &Segment, types: &[EventTypeId], window: TimeWindow, out: &mut Vec<u32>) {
    if !seg.overlaps(window.start_ms, window.end_ms) || !seg.bitmap().intersects(types) {
        return;
    }
    let before = out.len();
    let mut runs = 0usize;
    for &t in types {
        if !seg.bitmap().contains(t) {
            continue;
        }
        let pos = seg.positions_of(t);
        let lo = pos.partition_point(|&p| seg.ts[p as usize] < window.start_ms);
        let hi = pos.partition_point(|&p| seg.ts[p as usize] < window.end_ms);
        if lo < hi {
            out.extend_from_slice(&pos[lo..hi]);
            runs += 1;
        }
    }
    if runs > 1 {
        // Per-type runs interleave within the segment; position order is
        // append order, which is chronological with seq tie-breaking.
        out[before..].sort_unstable();
    }
}

/// Indexed retrieve: rows of any of `event_types` within `window`,
/// returned as cloned rows in global chronological order.
///
/// The clone is deliberate: in production this operation copies rows
/// from storage (SQLite pages / the segment arena) into process memory,
/// and that data movement is part of the `Retrieve` cost the paper
/// measures. The fused engine lanes use [`retrieve_project`] instead.
pub fn retrieve(
    store: &AppLogStore,
    event_types: &[EventTypeId],
    window: TimeWindow,
) -> Vec<BehaviorEvent> {
    // SQL `IN` semantics: duplicate listed types match rows once.
    let mut types: Vec<EventTypeId> = event_types.to_vec();
    types.sort_unstable();
    types.dedup();

    let mut out = Vec::new();
    let mut scratch: Vec<u32> = Vec::new();
    for seg in store.segments() {
        scratch.clear();
        segment_positions(seg, &types, window, &mut scratch);
        out.extend(scratch.iter().map(|&p| seg.materialize(p)));
    }
    scratch.clear();
    tail_positions(store, &types, window, &mut scratch);
    let tail = store.tail();
    out.extend(scratch.iter().map(|&p| tail[p as usize].clone()));
    out
}

/// Matching tail positions, merged into position order.
fn tail_positions(
    store: &AppLogStore,
    types: &[EventTypeId],
    window: TimeWindow,
    out: &mut Vec<u32>,
) {
    let tail = store.tail();
    let before = out.len();
    let mut runs = 0usize;
    for &t in types {
        let pos = store.tail_type_positions(t);
        let lo = pos.partition_point(|&p| tail[p as usize].timestamp_ms < window.start_ms);
        let hi = pos.partition_point(|&p| tail[p as usize].timestamp_ms < window.end_ms);
        if lo < hi {
            out.extend_from_slice(&pos[lo..hi]);
            runs += 1;
        }
    }
    if runs > 1 {
        out[before..].sort_unstable();
    }
}

/// One row decoded straight into an attr projection (output of the
/// fused Retrieve+Decode path).
#[derive(Debug, Clone, PartialEq)]
pub struct DecodedRow {
    /// Event timestamp.
    pub ts: TimestampMs,
    /// Log row id.
    pub seq: u64,
    /// `(attr id, value)` pairs of the requested projection, sorted.
    pub attrs: Vec<(AttrId, AttrValue)>,
}

/// Instrumentation of one fused Retrieve+Decode call.
#[derive(Debug, Clone, Copy, Default)]
pub struct RetrieveDecodeStats {
    /// Rows that survived pruning (retrieved and decoded).
    pub rows: u64,
    /// Time spent locating surviving rows (the `Retrieve` share).
    pub retrieve_ns: u64,
    /// Time spent decoding payload projections (the `Decode` share).
    pub decode_ns: u64,
    /// Segments whose rows were actually visited.
    pub segments_scanned: u64,
    /// Segments discarded by the zone map alone.
    pub segments_pruned: u64,
}

/// Fused `Retrieve` + projected `Decode` for one behavior type, pushed
/// down to segment granularity: zone maps discard whole segments, the
/// survivors' payloads are decoded from the arena without materializing
/// owned rows, and duplicate payloads within a segment are decoded once
/// (dictionary de-dup). Semantically identical to `retrieve` followed by
/// `codec.decode_project` per row — pinned by the differential tests.
pub fn retrieve_project(
    store: &AppLogStore,
    event_type: EventTypeId,
    window: TimeWindow,
    codec: &dyn AttrCodec,
    wanted: &[AttrId],
) -> Result<(Vec<DecodedRow>, RetrieveDecodeStats)> {
    let mut out = Vec::new();
    let mut stats = RetrieveDecodeStats::default();
    let types = [event_type];
    let mut scratch: Vec<u32> = Vec::new();
    let mut memo: HashMap<u32, Vec<(AttrId, AttrValue)>> = HashMap::new();

    for seg in store.segments() {
        let t0 = Instant::now();
        // Zone map first: a miss discards the segment without touching
        // its rows ("pruned"); anything past this point is a visit.
        if !seg.overlaps(window.start_ms, window.end_ms) || !seg.bitmap().contains(event_type) {
            stats.segments_pruned += 1;
            stats.retrieve_ns += t0.elapsed().as_nanos() as u64;
            continue;
        }
        scratch.clear();
        segment_positions(seg, &types, window, &mut scratch);
        stats.retrieve_ns += t0.elapsed().as_nanos() as u64;
        stats.segments_scanned += 1;
        if scratch.is_empty() {
            continue;
        }
        stats.rows += scratch.len() as u64;

        let t0 = Instant::now();
        let dedup = seg.unique_payloads() < seg.len();
        memo.clear();
        for &p in &scratch {
            let attrs = if dedup {
                let code = seg.payload_codes[p as usize];
                match memo.get(&code) {
                    Some(a) => a.clone(),
                    None => {
                        let a = codec.decode_project(seg.payload_at(p), wanted)?;
                        memo.insert(code, a.clone());
                        a
                    }
                }
            } else {
                codec.decode_project(seg.payload_at(p), wanted)?
            };
            out.push(DecodedRow {
                ts: seg.ts[p as usize],
                seq: seg.seq[p as usize],
                attrs,
            });
        }
        stats.decode_ns += t0.elapsed().as_nanos() as u64;
    }

    let t0 = Instant::now();
    scratch.clear();
    tail_positions(store, &types, window, &mut scratch);
    stats.retrieve_ns += t0.elapsed().as_nanos() as u64;
    if !scratch.is_empty() {
        stats.rows += scratch.len() as u64;
        let t0 = Instant::now();
        let tail = store.tail();
        for &p in &scratch {
            let r = &tail[p as usize];
            out.push(DecodedRow {
                ts: r.timestamp_ms,
                seq: r.seq_no,
                attrs: codec.decode_project(&r.payload, wanted)?,
            });
        }
        stats.decode_ns += t0.elapsed().as_nanos() as u64;
    }
    Ok((out, stats))
}

/// Reference retrieve: full-table scan. O(total rows); used by tests and
/// by the paper's Fig. 10-style op-cost probes as the unindexed worst
/// case.
pub fn retrieve_scan(
    store: &AppLogStore,
    event_types: &[EventTypeId],
    window: TimeWindow,
) -> Vec<BehaviorEvent> {
    store
        .iter()
        .filter(|r| window.contains(r.timestamp_ms) && event_types.contains(&r.event_type))
        .map(|r| r.to_event())
        .collect()
}

/// Count rows matching the query without materializing them (used by the
/// event evaluator to estimate `Num(E_i)` cheaply). Zone maps prune
/// whole segments exactly as in [`retrieve`].
pub fn count(store: &AppLogStore, event_type: EventTypeId, window: TimeWindow) -> usize {
    let mut n = 0usize;
    for seg in store.segments() {
        if !seg.overlaps(window.start_ms, window.end_ms) || !seg.bitmap().contains(event_type) {
            continue;
        }
        let pos = seg.positions_of(event_type);
        let lo = pos.partition_point(|&p| seg.ts[p as usize] < window.start_ms);
        let hi = pos.partition_point(|&p| seg.ts[p as usize] < window.end_ms);
        n += hi - lo;
    }
    let tail = store.tail();
    let pos = store.tail_type_positions(event_type);
    let lo = pos.partition_point(|&p| tail[p as usize].timestamp_ms < window.start_ms);
    let hi = pos.partition_point(|&p| tail[p as usize].timestamp_ms < window.end_ms);
    n + (hi - lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::codec::JsonishCodec;
    use crate::applog::store::StoreConfig;

    fn store_seg(segment_rows: usize) -> AppLogStore {
        let mut s = AppLogStore::new(StoreConfig {
            segment_rows,
            ..StoreConfig::default()
        });
        // Interleave 4 types over 100 rows, 1s apart.
        for i in 0..100i64 {
            s.append((i % 4) as EventTypeId, i * 1000, vec![i as u8])
                .unwrap();
        }
        s
    }

    fn store() -> AppLogStore {
        store_seg(16)
    }

    #[test]
    fn indexed_matches_scan_across_layouts() {
        for segment_rows in [1usize, 7, 16, usize::MAX] {
            let s = store_seg(segment_rows);
            let w = TimeWindow::last(80_000, 50_000);
            for types in [vec![0u16], vec![1, 3], vec![0, 1, 2, 3], vec![9]] {
                let a = retrieve(&s, &types, w);
                let b = retrieve_scan(&s, &types, w);
                assert_eq!(a.len(), b.len(), "seg={segment_rows} {types:?}");
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.seq_no, y.seq_no);
                    assert_eq!(x.payload, y.payload);
                }
            }
        }
    }

    #[test]
    fn results_are_chronological() {
        let s = store();
        let out = retrieve(&s, &[0, 1, 2, 3], TimeWindow::last(100_000, 100_000));
        assert_eq!(out.len(), 100);
        for pair in out.windows(2) {
            assert!(pair[0].timestamp_ms <= pair[1].timestamp_ms);
            assert!(pair[0].seq_no < pair[1].seq_no);
        }
    }

    #[test]
    fn window_end_is_exclusive() {
        let s = store();
        // Event at ts=50_000 must not be in [0, 50_000).
        let out = retrieve(
            &s,
            &[0, 1, 2, 3],
            TimeWindow {
                start_ms: 0,
                end_ms: 50_000,
            },
        );
        assert!(out.iter().all(|r| r.timestamp_ms < 50_000));
        assert_eq!(out.len(), 50);
    }

    #[test]
    fn window_start_is_inclusive() {
        let s = store();
        let out = retrieve(
            &s,
            &[0],
            TimeWindow {
                start_ms: 0,
                end_ms: 1,
            },
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].timestamp_ms, 0);
    }

    #[test]
    fn duplicate_types_match_rows_once() {
        let s = store();
        let w = TimeWindow::last(100_000, 100_000);
        assert_eq!(retrieve(&s, &[2, 2, 2], w).len(), retrieve(&s, &[2], w).len());
    }

    #[test]
    fn unknown_type_is_empty() {
        let s = store();
        assert!(retrieve(&s, &[42], TimeWindow::last(100_000, 100_000)).is_empty());
    }

    #[test]
    fn last_clamps_to_epoch_when_window_exceeds_history() {
        // Regression: `now < range_ms` used to produce a negative start.
        let w = TimeWindow::last(5_000, 60_000);
        assert_eq!(w.start_ms, 0);
        assert_eq!(w.end_ms, 5_000);
        let s = store();
        let out = retrieve(&s, &[0, 1, 2, 3], w);
        assert_eq!(out.len(), 5); // events at 0..5s
        // Unaffected when the window fits the history.
        assert_eq!(TimeWindow::last(60_000, 5_000).start_ms, 55_000);
    }

    #[test]
    fn count_matches_retrieve() {
        for segment_rows in [1usize, 16, usize::MAX] {
            let s = store_seg(segment_rows);
            let w = TimeWindow::last(70_000, 30_000);
            for t in 0..4u16 {
                assert_eq!(count(&s, t, w), retrieve(&s, &[t], w).len());
            }
        }
    }

    #[test]
    fn zone_maps_prune_segments_outside_the_window() {
        let mut s = AppLogStore::new(StoreConfig {
            segment_rows: 10,
            ..StoreConfig::default()
        });
        let codec = JsonishCodec;
        let payload = codec.encode(&[(0, AttrValue::Int(7))]);
        for i in 0..100i64 {
            s.append((i % 2) as u16, i * 1000, payload.clone()).unwrap();
        }
        assert_eq!(s.num_segments(), 10);
        // A window over the last 25% of the log must prune >= 70% of
        // segments via min/max timestamps alone.
        let w = TimeWindow::last(100_000, 25_000);
        let (rows, stats) = retrieve_project(&s, 0, w, &codec, &[0]).unwrap();
        assert_eq!(rows.len() as u64, stats.rows);
        assert!(
            stats.segments_pruned >= 7,
            "pruned {} of 10 segments",
            stats.segments_pruned
        );
        assert!(stats.segments_scanned <= 3);
        // A type absent from the log is pruned by the bitmap everywhere.
        let (rows, stats) = retrieve_project(&s, 9, w, &codec, &[0]).unwrap();
        assert!(rows.is_empty());
        assert_eq!(stats.segments_scanned, 0);
    }

    #[test]
    fn retrieve_project_equals_retrieve_then_decode_project() {
        let codec = JsonishCodec;
        for segment_rows in [1usize, 7, 64, usize::MAX] {
            let mut s = AppLogStore::new(StoreConfig {
                segment_rows,
                ..StoreConfig::default()
            });
            for i in 0..80i64 {
                // Only 5 distinct payloads: exercises the per-segment
                // decode memoization.
                let attrs = vec![
                    (0u16, AttrValue::Int(i % 5)),
                    (2u16, AttrValue::Str(format!("g{}", i % 5))),
                ];
                s.append((i % 3) as u16, i * 500, codec.encode(&attrs))
                    .unwrap();
            }
            let w = TimeWindow::last(35_000, 20_000);
            for wanted in [vec![], vec![0u16], vec![0, 2], vec![9]] {
                let (got, stats) = retrieve_project(&s, 1, w, &codec, &wanted).unwrap();
                let want: Vec<DecodedRow> = retrieve(&s, &[1], w)
                    .iter()
                    .map(|r| DecodedRow {
                        ts: r.timestamp_ms,
                        seq: r.seq_no,
                        attrs: codec.decode_project(&r.payload, &wanted).unwrap(),
                    })
                    .collect();
                assert_eq!(got, want, "seg={segment_rows} wanted={wanted:?}");
                assert_eq!(stats.rows as usize, want.len());
            }
        }
    }
}
