//! The event evaluator: cross-execution redundancy minimization
//! (paper §3.4).
//!
//! Consecutive model executions re-process overlapping behavior events.
//! AutoFeature caches *decoded attributes at behavior level* — per event
//! type, all of its events' needed attributes — so the dominant
//! `Retrieve`/`Decode` work is never repeated on overlapping rows.
//! Which behavior types to cache under a memory budget is a 0/1 knapsack
//! over per-type utility (`Num_Overlap × Cost_Opt`) and cost
//! (`Num × Size`); a greedy utility-to-cost-ratio policy gives a
//! 2-approximation with O(1) per-type ratio computation via term
//! decomposition.
//!
//! * [`entry`] — cached decoded rows per behavior type with watermarks,
//! * [`valuation`] — utility/cost metrics and term decomposition,
//! * [`policy`] — greedy / DP-knapsack / random / all-or-nothing,
//! * [`store`] — the memory-budgeted cache store,
//! * [`arbiter`] — the host-wide budget arbiter dividing one cap across
//!   the live sessions of a [`crate::coordinator::pool::SessionPool`].

pub mod arbiter;
pub mod entry;
pub mod policy;
pub mod store;
pub mod valuation;
