"""AOT compile path: lower the per-service JAX models to HLO text.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per service ``s`` in {cp, kp, sr, pr, vr}:

  artifacts/model_<s>.hlo.txt       HLO text consumed by rust runtime/
  artifacts/model_<s>.meta.txt      input signature: ``key value`` lines
  artifacts/model_<s>.expected.txt  sample input/output dump for the Rust
                                    end-to-end numerics test

Run via ``make artifacts`` (no-op when inputs are unchanged). Python never
runs on the request path — the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import SERVICE_CONFIGS, ModelConfig, example_inputs, make_inference_fn


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default HLO printer
    # elides big constants as `{...}`, which the Rust side's HLO *text*
    # parser silently reads back as zeros — every baked-in model weight
    # would vanish and the model would output sigmoid(0) = 0.5 forever.
    return comp.as_hlo_text(print_large_constants=True)


def lower_service(cfg: ModelConfig) -> str:
    fn = make_inference_fn(cfg)
    stat, seq, mask, cloud = (
        jax.ShapeDtypeStruct((cfg.n_stat,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.seq_len, cfg.seq_dim), jnp.float32),
        jax.ShapeDtypeStruct((cfg.seq_len,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n_cloud,), jnp.float32),
    )
    lowered = jax.jit(fn).lower(stat, seq, mask, cloud)
    return to_hlo_text(lowered)


def write_meta(cfg: ModelConfig, path: str) -> None:
    with open(path, "w") as f:
        f.write(f"service {cfg.name}\n")
        f.write(f"n_user {cfg.n_user}\n")
        f.write(f"n_device {cfg.n_device}\n")
        f.write(f"n_stat {cfg.n_stat}\n")
        f.write(f"seq_len {cfg.seq_len}\n")
        f.write(f"seq_dim {cfg.seq_dim}\n")
        f.write(f"n_cloud {cfg.n_cloud}\n")


def write_expected(cfg: ModelConfig, path: str) -> None:
    """Dump a deterministic sample (inputs flattened + expected output)."""
    fn = make_inference_fn(cfg)
    stat, seq, mask, cloud = example_inputs(cfg)
    (out,) = jax.jit(fn)(stat, seq, mask, cloud)
    with open(path, "w") as f:
        for name, arr in (
            ("stat", stat),
            ("seq", seq),
            ("seq_mask", mask),
            ("cloud", cloud),
        ):
            flat = jnp.ravel(arr)
            f.write(f"{name} {' '.join(repr(float(x)) for x in flat)}\n")
        f.write(f"output {float(out)!r}\n")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--services",
        default=",".join(SERVICE_CONFIGS),
        help="comma-separated subset of services to lower",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name in args.services.split(","):
        cfg = SERVICE_CONFIGS[name]
        hlo = lower_service(cfg)
        hlo_path = os.path.join(args.out_dir, f"model_{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(hlo)
        write_meta(cfg, os.path.join(args.out_dir, f"model_{name}.meta.txt"))
        write_expected(cfg, os.path.join(args.out_dir, f"model_{name}.expected.txt"))
        print(f"[aot] {name}: wrote {len(hlo)} chars -> {hlo_path}")


if __name__ == "__main__":
    main()
