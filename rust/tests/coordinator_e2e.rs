//! Coordinator end-to-end: the concurrent Layer-3 pipeline over a real
//! service workload, with PJRT model inference when artifacts exist.

use autofeature::coordinator::run_service;
use autofeature::harness::{self, Method};
use autofeature::workload::behavior::{ActivityLevel, Period};
use autofeature::workload::driver::SimConfig;
use autofeature::workload::services::{ServiceKind, ServiceSpec};

fn sim(interval_ms: i64) -> SimConfig {
    SimConfig {
        period: Period::Evening,
        activity: ActivityLevel::P70,
        warmup_ms: 20 * 60_000,
        duration_ms: 3 * 60_000,
        inference_interval_ms: interval_ms,
        seed: 99,
        ..SimConfig::default()
    }
}

#[test]
fn coordinator_runs_autofeature_pipeline() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::CP, &catalog);
    let mut extractor =
        harness::make_extractor(Method::AutoFeature, svc.features.clone(), &catalog, 256 * 1024)
            .unwrap();
    let report = run_service(&catalog, extractor.as_mut(), None, &sim(10_000)).unwrap();
    assert_eq!(report.requests, 18); // 3 min / 10 s
    assert!(report.events_logged > 25, "{}", report.events_logged);
    assert!(report.metrics.mean_ms() > 0.0);
}

#[test]
fn coordinator_with_real_model_inference() {
    let dir = harness::default_artifact_dir();
    let Some(model) = harness::try_load_model(&dir, ServiceKind::SR) else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::SR, &catalog);
    let mut extractor =
        harness::make_extractor(Method::AutoFeature, svc.features.clone(), &catalog, 256 * 1024)
            .unwrap();
    let backend: Option<&dyn autofeature::runtime::InferenceBackend> = Some(&model);
    let report = run_service(&catalog, extractor.as_mut(), backend, &sim(20_000)).unwrap();
    assert_eq!(report.requests, 9);
    let p = report.last_prediction;
    assert!(p > 0.0 && p < 1.0, "prediction {p} not a probability");
    // With the tiny model, extraction must dominate end-to-end time for
    // the naive pipeline; for AutoFeature it need not — but both stages
    // must be observed.
    assert!(report.metrics.mean_ms() > 0.0);
}

#[test]
fn concurrent_and_sequential_agree_on_feature_values() {
    let catalog = harness::eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::KP, &catalog);
    let cfg = sim(30_000);

    let mut a =
        harness::make_extractor(Method::AutoFeature, svc.features.clone(), &catalog, 256 * 1024)
            .unwrap();
    let seq = autofeature::workload::driver::run_simulation(&catalog, a.as_mut(), None, &cfg)
        .unwrap();

    let mut b =
        harness::make_extractor(Method::AutoFeature, svc.features.clone(), &catalog, 256 * 1024)
            .unwrap();
    let conc = run_service(&catalog, b.as_mut(), None, &cfg).unwrap();

    assert_eq!(seq.records.len(), conc.requests);
    assert_eq!(seq.events_logged, conc.events_logged);
    // Same per-op row totals => both pipelines saw identical log states.
    let seq_rows: u64 = seq
        .records
        .iter()
        .map(|r| r.extraction.breakdown.rows_decoded + r.extraction.breakdown.rows_from_cache)
        .sum();
    assert_eq!(
        seq_rows,
        conc.metrics.breakdown().rows_decoded + conc.metrics.breakdown().rows_from_cache
    );
}
