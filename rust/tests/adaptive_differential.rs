//! Adaptive re-lowering differential suite (ISSUE 9 acceptance).
//!
//! The tentpole invariant: **every replan is value-transparent**. An
//! adaptive session driven through workload shifts — bursty trigger
//! trains, diurnal density swings, one-time clock skew — must produce
//! feature values bit-identical to a never-replanned pinned-static twin
//! at every trigger, across all five services. The opted-in incremental
//! strategy space relaxes bit equality to the incremental layer's 1e-9
//! bar. The scheduler arms pin worker-count invariance and hibernation
//! transparency: the cost model's pre-sleep estimators must seed the
//! post-wake model so the replan sequence is unchanged.

use autofeature::coordinator::pool::SessionConfig;
use autofeature::coordinator::sched::{FleetScheduler, SchedConfig, SchedReport};
use autofeature::engine::config::EngineConfig;
use autofeature::engine::online::Engine;
use autofeature::features::value::FeatureValue;
use autofeature::harness::eval_catalog;
use autofeature::workload::behavior::{ActivityLevel, Period};
use autofeature::workload::driver::{run_simulation, SimConfig, SimOutcome, TriggerTrain};
use autofeature::workload::services::{ServiceKind, ServiceSpec};

/// The scenario suite: every train shape the cost model must cope with,
/// parameterized by the service's native trigger interval.
fn trains(interval: i64, duration: i64) -> Vec<(&'static str, TriggerTrain)> {
    vec![
        ("fixed", TriggerTrain::Fixed),
        (
            "bursty",
            TriggerTrain::Bursty {
                burst_len: 3,
                burst_interval_ms: interval,
                gap_ms: 10 * interval,
            },
        ),
        (
            "diurnal",
            TriggerTrain::Diurnal {
                phase_ms: (duration / 4).max(1),
                dense_interval_ms: interval,
                sparse_interval_ms: 6 * interval,
            },
        ),
        (
            "skew",
            TriggerTrain::Skew {
                jump_after_ms: duration / 2,
                skew_ms: 90_000,
            },
        ),
    ]
}

fn run(
    svc: &ServiceSpec,
    catalog: &autofeature::applog::schema::Catalog,
    cfg: EngineConfig,
    sim: &SimConfig,
) -> SimOutcome {
    let mut eng = Engine::new(svc.features.clone(), catalog, cfg).unwrap();
    run_simulation(catalog, &mut eng, None, sim).unwrap()
}

fn total_replans(out: &SimOutcome) -> u64 {
    out.records
        .iter()
        .map(|r| r.extraction.breakdown.replans)
        .sum()
}

/// Default strategy space ({one-shot, cached-rewalk} × filter modes):
/// bit-identical values against the pinned twin, per service × train.
#[test]
fn adaptive_matches_pinned_static_across_services_and_trains() {
    let catalog = eval_catalog();
    for kind in ServiceKind::ALL {
        let svc = ServiceSpec::build(kind, &catalog);
        let interval = svc.inference_interval_ms;
        let duration = (20 * interval).max(4 * 60_000);
        for (train_name, train) in trains(interval, duration) {
            let sim = SimConfig {
                period: Period::Evening,
                activity: ActivityLevel::P70,
                warmup_ms: 20 * 60_000,
                duration_ms: duration,
                inference_interval_ms: interval,
                train,
                seed: 2026,
                ..SimConfig::default()
            };
            let stat = run(&svc, &catalog, EngineConfig::autofeature(), &sim);
            let adap = run(&svc, &catalog, EngineConfig::adaptive(), &sim);
            assert_eq!(
                stat.records.len(),
                adap.records.len(),
                "{} {train_name}: trigger count",
                kind.id()
            );
            for (i, (s, a)) in stat.records.iter().zip(&adap.records).enumerate() {
                assert_eq!(s.now, a.now, "{} {train_name}: trigger {i} time", kind.id());
                assert_eq!(
                    s.extraction.values, a.extraction.values,
                    "{} {train_name}: trigger {i} values (replans so far: {})",
                    kind.id(),
                    total_replans(&adap)
                );
            }
        }
    }
}

/// `|a - b| <= 1e-9 · max(|a|, |b|, 1)` — the incremental layer's
/// documented equality bar.
fn approx_eq(a: &FeatureValue, b: &FeatureValue) -> bool {
    fn close(x: f64, y: f64) -> bool {
        (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0)
    }
    match (a, b) {
        (FeatureValue::Scalar(x), FeatureValue::Scalar(y)) => close(*x, *y),
        (FeatureValue::Vector(x), FeatureValue::Vector(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| close(*p, *q))
        }
        _ => false,
    }
}

/// Opted-in incremental space: the adaptive engine may re-lower into
/// `IncrementalDelta`, whose equality bar is 1e-9 rather than bit
/// identity. Compare against the pinned incremental twin.
#[test]
fn adaptive_incremental_space_stays_within_tolerance() {
    let catalog = eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::VR, &catalog);
    let interval = svc.inference_interval_ms;
    let duration = 4 * 60_000;
    for (train_name, train) in trains(interval, duration) {
        let sim = SimConfig {
            period: Period::Evening,
            activity: ActivityLevel::P70,
            warmup_ms: 20 * 60_000,
            duration_ms: duration,
            inference_interval_ms: interval,
            train,
            seed: 2027,
            ..SimConfig::default()
        };
        let stat = run(&svc, &catalog, EngineConfig::incremental(), &sim);
        let adap = run(
            &svc,
            &catalog,
            EngineConfig {
                adaptive_replan: true,
                ..EngineConfig::incremental()
            },
            &sim,
        );
        assert_eq!(stat.records.len(), adap.records.len(), "{train_name}");
        for (i, (s, a)) in stat.records.iter().zip(&adap.records).enumerate() {
            assert_eq!(
                s.extraction.values.len(),
                a.extraction.values.len(),
                "{train_name}: trigger {i} arity"
            );
            for (f, (x, y)) in s
                .extraction
                .values
                .iter()
                .zip(&a.extraction.values)
                .enumerate()
            {
                assert!(
                    approx_eq(x, y),
                    "{train_name}: trigger {i} feature {f}: {x:?} vs {y:?}"
                );
            }
        }
    }
}

fn sched_run(
    svc: &ServiceSpec,
    catalog: &autofeature::applog::schema::Catalog,
    users: &[SessionConfig],
    engine: EngineConfig,
    workers: usize,
    hibernate_after_ms: i64,
) -> SchedReport {
    let sched = FleetScheduler::new(
        svc.features.clone(),
        catalog,
        SchedConfig {
            workers,
            hibernate_after_ms,
            engine,
            record_values: true,
            ..SchedConfig::default()
        },
    )
    .unwrap();
    sched.run(catalog, users, None).unwrap()
}

/// Scheduler determinism: the adaptive fleet's values AND replan
/// sequence are invariant to the worker count and to hibernation
/// (pre-sleep cost-model state seeds the post-wake model), and the
/// values match a pinned-static fleet (value transparency at fleet
/// scale).
#[test]
fn scheduler_adaptive_is_worker_and_hibernation_invariant() {
    let catalog = eval_catalog();
    let svc = ServiceSpec::build(ServiceKind::VR, &catalog);
    let base = SimConfig {
        period: Period::Evening,
        activity: ActivityLevel::P70,
        warmup_ms: 6 * 60_000,
        duration_ms: 2 * 60_000,
        inference_interval_ms: svc.inference_interval_ms,
        seed: 77,
        ..SimConfig::default()
    };
    let users = SessionConfig::fleet(&base, 6);
    let baseline = sched_run(&svc, &catalog, &users, EngineConfig::adaptive(), 1, i64::MAX);
    for (label, workers, hib) in [("4 workers", 4usize, i64::MAX), ("hibernating", 2, 1)] {
        let other = sched_run(&svc, &catalog, &users, EngineConfig::adaptive(), workers, hib);
        assert_eq!(baseline.sessions.len(), other.sessions.len(), "{label}");
        for (a, b) in baseline.sessions.iter().zip(&other.sessions) {
            assert_eq!(a.user_id, b.user_id, "{label}");
            assert_eq!(a.requests, b.requests, "{label}: user {}", a.user_id);
            assert_eq!(a.values, b.values, "{label}: user {} values", a.user_id);
            assert_eq!(
                a.metrics.breakdown().replans,
                b.metrics.breakdown().replans,
                "{label}: user {} replan count",
                a.user_id
            );
        }
        assert_eq!(baseline.total_replans(), other.total_replans(), "{label}");
    }
    // Fleet-scale value transparency against the pinned static engine.
    let pinned = sched_run(&svc, &catalog, &users, EngineConfig::autofeature(), 2, i64::MAX);
    assert_eq!(pinned.total_replans(), 0, "static engines never replan");
    for (a, p) in baseline.sessions.iter().zip(&pinned.sessions) {
        assert_eq!(a.values, p.values, "user {}: adaptive vs pinned values", a.user_id);
    }
}
