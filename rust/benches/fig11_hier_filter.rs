//! Bench: Fig. 11 — hierarchical vs direct fused filter.
//! Regenerates the corresponding paper figure (see DESIGN.md §3).
//! `BENCH_QUICK=1` shrinks the workload for smoke runs.

mod common;

use autofeature::harness::experiments;

fn main() {
    common::run("fig11_hier_filter", || experiments::fig11_hier_filter(common::scale()).map(|_| ()));
}
