//! Cached decoded rows per behavior type.

use std::collections::VecDeque;

use crate::applog::event::{AttrId, AttrValue, EventTypeId, TimestampMs};

/// One cached row: the needed-attribute projection of a decoded event.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedRow {
    /// Event timestamp.
    pub ts: TimestampMs,
    /// Log row id.
    pub seq: u64,
    /// Projection of the decoded attributes onto the type's attr union,
    /// sorted by id.
    pub attrs: Vec<(AttrId, AttrValue)>,
}

impl CachedRow {
    /// Approximate in-memory size (bytes) for budget accounting.
    ///
    /// Capacity-aware: the attrs `Vec` is charged at its *capacity*
    /// times the real slot size (not a flat header constant), and
    /// string values charge their heap buffers at capacity too — the
    /// quantities the allocator actually reserves. Keeping this model
    /// honest keeps [`CachedLane::bytes`] (and with it the engine's
    /// budget enforcement) from drifting under the real footprint.
    pub fn approx_size(&self) -> usize {
        let slot = std::mem::size_of::<(AttrId, AttrValue)>();
        16 // ts + seq
            + std::mem::size_of::<Vec<(AttrId, AttrValue)>>()
            + self.attrs.capacity() * slot
            + self
                .attrs
                .iter()
                .map(|(_, v)| v.heap_size())
                .sum::<usize>()
    }
}

/// All cached rows of one behavior type, chronological, plus the
/// watermark up to which the log has been ingested.
#[derive(Debug, Clone)]
pub struct CachedLane {
    /// The behavior type.
    pub event_type: EventTypeId,
    /// Rows, ascending `(ts, seq)`.
    pub rows: VecDeque<CachedRow>,
    /// End (exclusive) of the ingested interval: all log rows of this
    /// type with `ts < watermark` within the retention window are
    /// present.
    pub watermark: TimestampMs,
    /// Cached byte total (kept incrementally).
    bytes: usize,
}

impl CachedLane {
    /// Empty lane with watermark at the retention-window start.
    pub fn new(event_type: EventTypeId, watermark: TimestampMs) -> Self {
        CachedLane {
            event_type,
            rows: VecDeque::new(),
            watermark,
            bytes: 0,
        }
    }

    /// Cached bytes.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Number of cached rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the lane holds no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Append a freshly decoded row (must be newest).
    pub fn push(&mut self, row: CachedRow) {
        debug_assert!(
            self.rows
                .back()
                .map_or(true, |b| (b.ts, b.seq) < (row.ts, row.seq)),
            "cache rows must stay chronological"
        );
        self.bytes += row.approx_size();
        self.rows.push_back(row);
    }

    /// Drop rows older than `cutoff` (retention = the type's max feature
    /// window). Returns the evicted rows, still in chronological order —
    /// the incremental compute layer retracts exactly these from its
    /// persistent accumulators (bytes freed = their summed
    /// [`CachedRow::approx_size`]). When nothing expires the returned
    /// `Vec` is empty and allocation-free, so callers that discard the
    /// result (the classic path, `CacheStore::prune`) only pay for
    /// evictions that actually happened.
    pub fn prune_before(&mut self, cutoff: TimestampMs) -> Vec<CachedRow> {
        let n = self.rows.partition_point(|r| r.ts < cutoff);
        let evicted: Vec<CachedRow> = self.rows.drain(..n).collect();
        self.bytes -= evicted.iter().map(|r| r.approx_size()).sum::<usize>();
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(ts: i64, seq: u64) -> CachedRow {
        CachedRow {
            ts,
            seq,
            attrs: vec![(0, AttrValue::Int(ts)), (1, AttrValue::Str("xy".into()))],
        }
    }

    #[test]
    fn bytes_track_push_and_prune() {
        let mut lane = CachedLane::new(0, 0);
        for i in 0..10 {
            lane.push(row(i * 1000, i as u64));
        }
        let full = lane.bytes();
        assert_eq!(full, lane.rows.iter().map(|r| r.approx_size()).sum());
        let evicted = lane.prune_before(5000);
        let freed: usize = evicted.iter().map(|r| r.approx_size()).sum();
        assert_eq!(lane.len(), 5);
        assert_eq!(lane.bytes(), full - freed);
        // Evicted rows come back in chronological order (the incremental
        // layer retracts them in exactly this order).
        let ts: Vec<i64> = evicted.iter().map(|r| r.ts).collect();
        assert_eq!(ts, vec![0, 1000, 2000, 3000, 4000]);
    }

    #[test]
    fn approx_size_is_capacity_aware() {
        // A string with slack capacity must be charged at capacity, not
        // len — otherwise the budget accounting drifts under the real
        // heap footprint.
        let mut s = String::with_capacity(128);
        s.push_str("ab");
        let fat = CachedRow {
            ts: 0,
            seq: 0,
            attrs: vec![(0, AttrValue::Str(s))],
        };
        let lean = CachedRow {
            ts: 0,
            seq: 0,
            attrs: vec![(0, AttrValue::Str("ab".to_string()))],
        };
        assert!(
            fat.approx_size() >= lean.approx_size() + 128 - "ab".len(),
            "fat {} vs lean {}",
            fat.approx_size(),
            lean.approx_size()
        );
        // And the Vec buffer itself is charged at capacity.
        let mut attrs = Vec::with_capacity(16);
        attrs.push((0u16, AttrValue::Int(1)));
        let slack = CachedRow { ts: 0, seq: 0, attrs };
        let tight = CachedRow {
            ts: 0,
            seq: 0,
            attrs: vec![(0, AttrValue::Int(1))],
        };
        let slot = std::mem::size_of::<(AttrId, AttrValue)>();
        assert_eq!(
            slack.approx_size(),
            tight.approx_size() + (16 - tight.attrs.capacity()) * slot
        );
    }

    #[test]
    fn prune_keeps_boundary_row() {
        let mut lane = CachedLane::new(0, 0);
        lane.push(row(1000, 0));
        lane.push(row(2000, 1));
        lane.prune_before(2000);
        assert_eq!(lane.len(), 1);
        assert_eq!(lane.rows[0].ts, 2000);
    }

    #[test]
    #[should_panic(expected = "chronological")]
    #[cfg(debug_assertions)]
    fn push_out_of_order_panics_in_debug() {
        let mut lane = CachedLane::new(0, 0);
        lane.push(row(2000, 1));
        lane.push(row(1000, 0));
    }
}
