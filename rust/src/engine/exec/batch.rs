//! Batch-grain lane execution ([`ExecMode::Batch`]): Scan → Project →
//! Filter → Aggregate over `ColumnBatch + SelectionVector` instead of a
//! materialized row stream.
//!
//! The uncached one-shot path runs entirely here: per column batch, the
//! zone map either discards it outright or the bitmask kernel produces a
//! selection vector; surviving positions decode **per unique payload**
//! (not per row) into a reusable [`DecodedBatch`], and the lane walk
//! consumes the selection directly — timestamps and seq_nos are read
//! from the batch's zero-copy columns, attribute values from the
//! decoded-payload table. No `BehaviorEvent`, `DecodedRow` or
//! `CachedRow` is ever materialized (`ExecCounters::rows_materialized`
//! stays 0; a release-mode test and a CI step assert it).
//!
//! Cached lanes are already materialized rows by design; for them
//! [`walk_rows`] provides the batch-grain Filter+Aggregate over
//! contiguous row slices (one per `VecDeque` half plus the fresh spill),
//! replacing the per-row iterator chain.
//!
//! **Bit-identity with the row walk** (the differential suite's
//! contract): each feature sink belongs to exactly one member of one
//! window group per lane, and both grains feed any member its
//! qualifying rows chronologically with the member's attrs in the same
//! per-row order — so every sink observes the identical push sequence
//! and the executor's rows-in/rows-out counters match exactly. Only the
//! *boundary comparison* count differs: the row walk's monotone pointer
//! pays O(rows + groups) per lane, the batch walk one binary search per
//! (group, batch).
//!
//! [`ExecMode::Batch`]: crate::optimizer::lower::ExecMode::Batch

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::applog::arena::SharedDecodeCache;
use crate::applog::codec::AttrCodec;
use crate::applog::event::{AttrId, AttrValue, TimestampMs};
use crate::applog::query::{column_batches, ColumnBatch, SelectionVector};
use crate::applog::store::AppLogStore;
use crate::cache::entry::CachedRow;
use crate::optimizer::hierarchical::lookup;
use crate::optimizer::lower::{FilterMode, Stage};
use crate::optimizer::plan::{FeatureAcc, FusedLane};

use super::pipeline::ExecCounters;

const ABSENT: u32 = u32::MAX;

/// Rows / pushes / boundary comparisons of one batch-grain walk.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct WalkStats {
    /// Selected rows fed to the Filter stage.
    pub rows: u64,
    /// Observations pushed into member accumulators.
    pub pushes: u64,
    /// Window-boundary comparisons (binary-search probes per
    /// (group, batch) on the hierarchical walk; per (row, member) on the
    /// direct walk — matching the row-walk ablation's cost model).
    pub cmps: u64,
}

impl WalkStats {
    fn merge(&mut self, o: WalkStats) {
        self.rows += o.rows;
        self.pushes += o.pushes;
        self.cmps += o.cmps;
    }
}

/// Reusable per-batch decode table: the selection's payloads decoded
/// into the lane's attr-union projection, **once per unique payload**
/// (segment batches are dictionary-coded, so equal codes share one
/// decode), plus a dense union-slot table per unique payload so member
/// pushes index attr values in O(1) — the batch-grain analogue of the
/// row walker's per-row merge-join.
#[derive(Debug, Default)]
pub(crate) struct DecodedBatch {
    /// Decoded `(attr, value)` pairs of all unique payloads, flat.
    flat: Vec<(AttrId, AttrValue)>,
    /// Per unique payload: `(start, len)` into `flat`.
    uniq: Vec<(u32, u32)>,
    /// Per unique payload: `union_len` slots, `slots[u * union_len + j]`
    /// = index of `union[j]` within the payload's attrs, or `ABSENT`.
    slots: Vec<u32>,
    /// Per selected row (parallel to the selection): unique-payload id.
    row_uniq: Vec<u32>,
    /// Dictionary code → unique-payload id memo (segment batches).
    memo: HashMap<u32, u32>,
    union_len: usize,
}

impl DecodedBatch {
    /// Decode the selection's surviving payloads into `union` order.
    /// With a cross-session `shared` cache, each unique payload's
    /// projected decode is memoized across every session served under
    /// the same fused trigger instant (misses count decode executions).
    pub(crate) fn decode(
        &mut self,
        cb: &ColumnBatch<'_>,
        sel: &SelectionVector,
        codec: &dyn AttrCodec,
        union: &[AttrId],
        shared: Option<&SharedDecodeCache>,
    ) -> Result<()> {
        self.flat.clear();
        self.uniq.clear();
        self.slots.clear();
        self.row_uniq.clear();
        self.memo.clear();
        self.union_len = union.len();
        let shared_fp =
            shared.map(|cache| (cache, SharedDecodeCache::union_fingerprint(union)));
        let dedup = cb.dedup_payloads();
        for &p in sel.positions() {
            let u = if dedup {
                let code = cb
                    .payload_code(p)
                    .expect("dedup batches are dictionary-coded segments");
                match self.memo.get(&code) {
                    Some(&u) => u,
                    None => {
                        let u = self.push_unique(
                            cb.payload_at(p),
                            cb.payload_arc(p),
                            shared_fp,
                            codec,
                            union,
                        )?;
                        self.memo.insert(code, u);
                        u
                    }
                }
            } else {
                self.push_unique(cb.payload_at(p), cb.payload_arc(p), shared_fp, codec, union)?
            };
            self.row_uniq.push(u);
        }
        Ok(())
    }

    fn push_unique(
        &mut self,
        payload: &[u8],
        interned: Option<std::sync::Arc<[u8]>>,
        shared: Option<(&SharedDecodeCache, u64)>,
        codec: &dyn AttrCodec,
        union: &[AttrId],
    ) -> Result<u32> {
        let attrs = match shared {
            Some((cache, fp)) => cache.decode_project(payload, interned, fp, codec, union)?,
            None => codec.decode_project(payload, union)?,
        };
        let start = self.flat.len() as u32;
        // Merge-join decoded attrs (sorted) x union (sorted) into the
        // payload's slot row.
        let base = self.slots.len();
        self.slots.resize(base + union.len(), ABSENT);
        let (mut i, mut j) = (0usize, 0usize);
        while i < attrs.len() && j < union.len() {
            match attrs[i].0.cmp(&union[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    self.slots[base + j] = i as u32;
                    i += 1;
                    j += 1;
                }
            }
        }
        self.uniq.push((start, attrs.len() as u32));
        self.flat.extend(attrs);
        Ok((self.uniq.len() - 1) as u32)
    }

    /// Decoded attrs of one unique payload.
    #[inline]
    fn attrs_of(&self, u: u32) -> &[(AttrId, AttrValue)] {
        let (start, len) = self.uniq[u as usize];
        &self.flat[start as usize..(start + len) as usize]
    }

    /// Union-slot row of one unique payload.
    #[inline]
    fn slots_of(&self, u: u32) -> &[u32] {
        let base = u as usize * self.union_len;
        &self.slots[base..base + self.union_len]
    }
}

/// First index of `pos` whose timestamp is `>= lo_ts` (the group's
/// qualifying suffix), counting every probe as a boundary comparison.
fn suffix_start(
    cb: &ColumnBatch<'_>,
    pos: &[u32],
    lo_ts: TimestampMs,
    cmps: &mut u64,
) -> usize {
    let (mut lo, mut hi) = (0usize, pos.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        *cmps += 1;
        if cb.ts_at(pos[mid]) < lo_ts {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// [`suffix_start`] over a cached-row slice.
fn suffix_start_rows(rows: &[CachedRow], lo_ts: TimestampMs, cmps: &mut u64) -> usize {
    let (mut lo, mut hi) = (0usize, rows.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        *cmps += 1;
        if rows[mid].ts < lo_ts {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Filter + Aggregate over one batch's selection vector: per window
/// group, binary-search the qualifying suffix once, then feed every
/// member its rows straight from the decode table.
pub(crate) fn walk_selection(
    lane: &FusedLane,
    mode: FilterMode,
    now: TimestampMs,
    cb: &ColumnBatch<'_>,
    sel: &SelectionVector,
    dec: &DecodedBatch,
    sinks: &mut [FeatureAcc],
) -> WalkStats {
    let pos = sel.positions();
    let mut st = WalkStats {
        rows: pos.len() as u64,
        ..Default::default()
    };
    match mode {
        FilterMode::Hierarchical => {
            for group in &lane.groups {
                let lo_ts = now - group.window.duration_ms;
                let start = suffix_start(cb, pos, lo_ts, &mut st.cmps);
                for m in &group.members {
                    for (k, &p) in pos.iter().enumerate().skip(start) {
                        let u = dec.row_uniq[k];
                        let slots = dec.slots_of(u);
                        let attrs = dec.attrs_of(u);
                        for &slot in &m.attr_slots {
                            let idx = slots[slot as usize];
                            if idx != ABSENT {
                                let v = &attrs[idx as usize].1;
                                sinks[m.feature_idx].push(cb.ts_at(p), cb.seq_at(p), v);
                                st.pushes += 1;
                            }
                        }
                    }
                }
            }
        }
        FilterMode::Direct => {
            // The ablation's cost model: one comparison per (row,
            // member), matching `DirectWalker` exactly.
            for group in &lane.groups {
                let w = group.window.duration_ms;
                for m in &group.members {
                    for (k, &p) in pos.iter().enumerate() {
                        st.cmps += 1;
                        if w >= now - cb.ts_at(p) {
                            let attrs = dec.attrs_of(dec.row_uniq[k]);
                            for &a in &m.attrs {
                                if let Some(v) = lookup(attrs, a) {
                                    sinks[m.feature_idx].push(cb.ts_at(p), cb.seq_at(p), v);
                                    st.pushes += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    st
}

/// Batch-grain Filter + Aggregate over a contiguous cached-row slice —
/// the cached-rewalk strategy's walk, fed one slice per `VecDeque` half
/// plus the fresh spill (chronological concatenation).
pub(crate) fn walk_rows(
    lane: &FusedLane,
    mode: FilterMode,
    now: TimestampMs,
    rows: &[CachedRow],
    sinks: &mut [FeatureAcc],
) -> WalkStats {
    let mut st = WalkStats {
        rows: rows.len() as u64,
        ..Default::default()
    };
    match mode {
        FilterMode::Hierarchical => {
            for group in &lane.groups {
                let lo_ts = now - group.window.duration_ms;
                let start = suffix_start_rows(rows, lo_ts, &mut st.cmps);
                for m in &group.members {
                    for r in &rows[start..] {
                        for &a in &m.attrs {
                            if let Some(v) = lookup(&r.attrs, a) {
                                sinks[m.feature_idx].push(r.ts, r.seq, v);
                                st.pushes += 1;
                            }
                        }
                    }
                }
            }
        }
        FilterMode::Direct => {
            for group in &lane.groups {
                let w = group.window.duration_ms;
                for m in &group.members {
                    for r in rows {
                        st.cmps += 1;
                        if w >= now - r.ts {
                            for &a in &m.attrs {
                                if let Some(v) = lookup(&r.attrs, a) {
                                    sinks[m.feature_idx].push(r.ts, r.seq, v);
                                    st.pushes += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    st
}

/// Run one uncached lane end-to-end at batch grain, metering every
/// operator. The Scan's zone checks are timed even for pruned batches
/// (matching the row path's `retrieve_ns` semantics); `batches` counts
/// only batches that survive the zone map.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_lane_oneshot(
    lane: &FusedLane,
    mode: FilterMode,
    codec: &dyn AttrCodec,
    store: &AppLogStore,
    now: TimestampMs,
    sinks: &mut [FeatureAcc],
    c: &mut ExecCounters,
    boundary_cmps: &mut u64,
    shared: Option<&SharedDecodeCache>,
) -> Result<()> {
    let window = lane.max_window.window_at(now);
    let mut sel = SelectionVector::new();
    let mut dec = DecodedBatch::default();
    for cb in column_batches(store) {
        // Scan: zone-map skip, then the bitmask selection kernel.
        let t0 = Instant::now();
        let pruned =
            cb.is_segment() && (!cb.overlaps(window) || !cb.contains_type(lane.event_type));
        if pruned {
            c.stage_mut(Stage::Scan).add_ns(t0);
            continue;
        }
        cb.select_types(&[lane.event_type], window, &mut sel);
        let scan = c.stage_mut(Stage::Scan);
        scan.add_ns(t0);
        scan.batches += 1;
        scan.rows_out += sel.len() as u64;
        if sel.is_empty() {
            continue;
        }

        // Project: per-unique-payload decode into the attr union.
        let t0 = Instant::now();
        dec.decode(&cb, &sel, codec, &lane.attr_union, shared)?;
        let project = c.stage_mut(Stage::Project);
        project.add_ns(t0);
        project.batches += 1;
        project.rows_in += sel.len() as u64;
        project.rows_out += sel.len() as u64;

        // Filter + Aggregate directly over the selection.
        let t0 = Instant::now();
        let ws = walk_selection(lane, mode, now, &cb, &sel, &dec, sinks);
        let f = c.stage_mut(Stage::Filter);
        f.add_ns(t0);
        f.batches += 1;
        f.rows_in += ws.rows;
        f.rows_out += ws.pushes;
        c.stage_mut(Stage::Aggregate).rows_in += ws.pushes;
        *boundary_cmps += ws.cmps;
    }
    Ok(())
}

/// Batch-grain cached-rewalk over a lane's row set: one walk per
/// contiguous slice, chronological. Returns `(stats, batches walked)`.
pub(crate) fn walk_cached_lane(
    lane: &FusedLane,
    mode: FilterMode,
    now: TimestampMs,
    cached: &crate::cache::entry::CachedLane,
    fresh: &[CachedRow],
    sinks: &mut [FeatureAcc],
) -> (WalkStats, u64) {
    let (a, b) = cached.rows.as_slices();
    let mut st = WalkStats::default();
    let mut batches = 0u64;
    for slice in [a, b, fresh] {
        if slice.is_empty() {
            continue;
        }
        st.merge(walk_rows(lane, mode, now, slice, sinks));
        batches += 1;
    }
    (st, batches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::codec::JsonishCodec;
    use crate::applog::query::TimeWindow;
    use crate::applog::store::{AppLogStore, StoreConfig};
    use crate::features::compute::CompFunc;
    use crate::features::spec::{FeatureId, FeatureSpec, TimeRange};
    use crate::features::value::FeatureValue;
    use crate::optimizer::fusion::fuse;
    use crate::optimizer::hierarchical::{DirectWalker, LaneWalker, RowView};

    fn specs() -> Vec<FeatureSpec> {
        (0..6)
            .map(|i| {
                FeatureSpec {
                    id: FeatureId(i as u32),
                    name: format!("f{i}"),
                    event_types: vec![1],
                    window: TimeRange::mins([5, 30, 60][i % 3]),
                    attrs: vec![(i % 2) as u16],
                    comp: [CompFunc::Count, CompFunc::Sum][i % 2],
                }
                .normalized()
            })
            .collect()
    }

    fn store(segment_rows: usize) -> AppLogStore {
        let codec = JsonishCodec;
        let mut s = AppLogStore::new(StoreConfig {
            segment_rows,
            ..StoreConfig::default()
        });
        for i in 0..200i64 {
            // Payloads repeat with period 21 so segment dictionaries
            // actually dedup and the memo path gets exercised.
            let attrs = vec![
                (0u16, AttrValue::Int(i % 7)),
                (1u16, AttrValue::Int(i % 3)),
            ];
            s.append((i % 3) as u16, i * 30_000, codec.encode(&attrs))
                .unwrap();
        }
        s
    }

    #[test]
    fn batch_walk_matches_row_walkers_bit_for_bit() {
        let specs = specs();
        let plan = fuse(&specs, true);
        let lane = &plan.lanes[0];
        let now = 200 * 30_000;
        let window = lane.max_window.window_at(now);
        let codec = JsonishCodec;

        for segment_rows in [1usize, 7, 64, usize::MAX] {
            let s = store(segment_rows);
            for mode in [FilterMode::Hierarchical, FilterMode::Direct] {
                // Batch grain.
                let mut sinks_b: Vec<_> =
                    specs.iter().map(|f| FeatureAcc::new(f, now)).collect();
                let mut sel = SelectionVector::new();
                let mut dec = DecodedBatch::default();
                let mut bst = WalkStats::default();
                for cb in column_batches(&s) {
                    cb.select_types(&[lane.event_type], window, &mut sel);
                    if sel.is_empty() {
                        continue;
                    }
                    dec.decode(&cb, &sel, &codec, &lane.attr_union, None).unwrap();
                    bst.merge(walk_selection(
                        lane, mode, now, &cb, &sel, &dec, &mut sinks_b,
                    ));
                }

                // Row grain over the same projected rows.
                let (rows, _) = crate::applog::query::retrieve_project(
                    &s,
                    lane.event_type,
                    window,
                    &codec,
                    &lane.attr_union,
                )
                .unwrap();
                let mut sinks_r: Vec<_> =
                    specs.iter().map(|f| FeatureAcc::new(f, now)).collect();
                let (r_rows, r_pushes) = match mode {
                    FilterMode::Hierarchical => {
                        let mut w = LaneWalker::new(lane, now);
                        for r in &rows {
                            let rv = RowView {
                                ts: r.ts,
                                seq: r.seq,
                                attrs: &r.attrs,
                            };
                            w.push_row(lane, rv, &mut sinks_r);
                        }
                        (w.rows, w.pushes)
                    }
                    FilterMode::Direct => {
                        let mut w = DirectWalker::new();
                        for r in &rows {
                            let rv = RowView {
                                ts: r.ts,
                                seq: r.seq,
                                attrs: &r.attrs,
                            };
                            w.push_row(lane, now, rv, &mut sinks_r);
                        }
                        (w.rows, w.pushes)
                    }
                };
                assert_eq!(bst.rows, r_rows, "seg={segment_rows} {mode:?}");
                assert_eq!(bst.pushes, r_pushes, "seg={segment_rows} {mode:?}");
                let vb: Vec<FeatureValue> =
                    sinks_b.into_iter().map(|x| x.finish()).collect();
                let vr: Vec<FeatureValue> =
                    sinks_r.into_iter().map(|x| x.finish()).collect();
                assert_eq!(vb, vr, "seg={segment_rows} {mode:?}");
            }
        }
    }

    #[test]
    fn cached_slice_walk_matches_lane_walker() {
        let specs = specs();
        let plan = fuse(&specs, true);
        let lane = &plan.lanes[0];
        let now = 3_600_000i64;
        let rows: Vec<CachedRow> = (0..120)
            .map(|i| CachedRow {
                ts: i * 30_000,
                seq: i as u64,
                attrs: vec![
                    (0u16, AttrValue::Int(i % 5)),
                    (1u16, AttrValue::Float(i as f64)),
                ],
            })
            .collect();
        for mode in [FilterMode::Hierarchical, FilterMode::Direct] {
            let mut sinks_b: Vec<_> = specs.iter().map(|f| FeatureAcc::new(f, now)).collect();
            // Feed as two slices — the VecDeque halves of a real lane.
            let mut st = walk_rows(lane, mode, now, &rows[..50], &mut sinks_b);
            st.merge(walk_rows(lane, mode, now, &rows[50..], &mut sinks_b));

            let mut sinks_r: Vec<_> = specs.iter().map(|f| FeatureAcc::new(f, now)).collect();
            let pushes = match mode {
                FilterMode::Hierarchical => {
                    let mut w = LaneWalker::new(lane, now);
                    for r in &rows {
                        let rv = RowView {
                            ts: r.ts,
                            seq: r.seq,
                            attrs: &r.attrs,
                        };
                        w.push_row(lane, rv, &mut sinks_r);
                    }
                    w.pushes
                }
                FilterMode::Direct => {
                    let mut w = DirectWalker::new();
                    for r in &rows {
                        let rv = RowView {
                            ts: r.ts,
                            seq: r.seq,
                            attrs: &r.attrs,
                        };
                        w.push_row(lane, now, rv, &mut sinks_r);
                    }
                    w.pushes
                }
            };
            assert_eq!(st.rows, rows.len() as u64);
            assert_eq!(st.pushes, pushes, "{mode:?}");
            let vb: Vec<FeatureValue> = sinks_b.into_iter().map(|x| x.finish()).collect();
            let vr: Vec<FeatureValue> = sinks_r.into_iter().map(|x| x.finish()).collect();
            assert_eq!(vb, vr, "{mode:?}");
        }
    }

    #[test]
    fn decode_table_memoizes_segment_payloads() {
        let codec = JsonishCodec;
        let s = store(64); // payloads repeat: dictionaries dedup
        let union: Vec<u16> = vec![0, 1];
        let w = TimeWindow::last(200 * 30_000, 200 * 30_000);
        let mut sel = SelectionVector::new();
        let mut dec = DecodedBatch::default();
        for cb in column_batches(&s) {
            cb.select_types(&[1], w, &mut sel);
            if sel.is_empty() {
                continue;
            }
            dec.decode(&cb, &sel, &codec, &union, None).unwrap();
            assert_eq!(dec.row_uniq.len(), sel.len());
            if cb.is_segment() {
                assert!(dec.uniq.len() <= sel.len());
            }
            // Every row's table entry equals a direct projected decode.
            for (k, &p) in sel.positions().iter().enumerate() {
                let want = codec.decode_project(cb.payload_at(p), &union).unwrap();
                assert_eq!(dec.attrs_of(dec.row_uniq[k]), want.as_slice());
            }
        }
    }
}
