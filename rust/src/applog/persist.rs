//! App-log persistence (the SQLite-analogue's on-disk role).
//!
//! Mobile app logs survive process restarts; this module gives
//! [`AppLogStore`] a compact binary snapshot format:
//!
//! ```text
//! magic "AFLG" | version u16 | row_count u64 |
//!   ( seq u64 | event_type u16 | ts i64 | payload_len u32 | payload )*
//! ```
//!
//! Snapshots round-trip exactly (rows, order, payload bytes) and load
//! validates magic/version/lengths, so a corrupted file never produces a
//! silently wrong log.

use anyhow::{bail, Context, Result};

use super::store::{AppLogStore, StoreConfig};

const MAGIC: &[u8; 4] = b"AFLG";
const VERSION: u16 = 1;

/// Serialize the live log to a snapshot blob.
pub fn to_bytes(store: &AppLogStore) -> Vec<u8> {
    let rows = store.rows();
    let mut out = Vec::with_capacity(14 + rows.iter().map(|r| 22 + r.payload.len()).sum::<usize>());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(rows.len() as u64).to_le_bytes());
    for r in rows {
        out.extend_from_slice(&r.seq_no.to_le_bytes());
        out.extend_from_slice(&r.event_type.to_le_bytes());
        out.extend_from_slice(&r.timestamp_ms.to_le_bytes());
        out.extend_from_slice(&(r.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&r.payload);
    }
    out
}

/// Load a snapshot blob into a fresh store.
pub fn from_bytes(data: &[u8], cfg: StoreConfig) -> Result<AppLogStore> {
    let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
        if *i + n > data.len() {
            bail!("truncated snapshot at offset {i}");
        }
        let s = &data[*i..*i + n];
        *i += n;
        Ok(s)
    };
    let mut i = 0usize;
    if take(&mut i, 4)? != MAGIC {
        bail!("bad snapshot magic");
    }
    let version = u16::from_le_bytes(take(&mut i, 2)?.try_into().unwrap());
    if version != VERSION {
        bail!("unsupported snapshot version {version}");
    }
    let count = u64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap()) as usize;
    let mut store = AppLogStore::new(cfg);
    let mut expected_seq: Option<u64> = None;
    for _ in 0..count {
        let seq = u64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap());
        let event_type = u16::from_le_bytes(take(&mut i, 2)?.try_into().unwrap());
        let ts = i64::from_le_bytes(take(&mut i, 8)?.try_into().unwrap());
        let len = u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap()) as usize;
        let payload = take(&mut i, len)?.to_vec();
        if let Some(e) = expected_seq {
            if seq <= e {
                bail!("non-monotonic seq {seq} after {e}");
            }
        }
        expected_seq = Some(seq);
        store
            .append(event_type, ts, payload)
            .context("snapshot rows out of chronological order")?;
    }
    if i != data.len() {
        bail!("trailing garbage after snapshot ({} bytes)", data.len() - i);
    }
    Ok(store)
}

/// Write a snapshot to a file.
pub fn save(store: &AppLogStore, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, to_bytes(store)).with_context(|| format!("writing {}", path.display()))
}

/// Load a snapshot from a file.
pub fn load(path: &std::path::Path, cfg: StoreConfig) -> Result<AppLogStore> {
    from_bytes(
        &std::fs::read(path).with_context(|| format!("reading {}", path.display()))?,
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::codec::{AttrCodec, JsonishCodec};
    use crate::applog::schema::{Catalog, CatalogConfig};
    use crate::util::rng::SimRng;

    fn populated() -> AppLogStore {
        let cat = Catalog::generate(&CatalogConfig::small(), 1);
        let mut rng = SimRng::seed_from_u64(2);
        let mut s = AppLogStore::new(StoreConfig::default());
        for i in 0..100i64 {
            let t = (i % 4) as u16;
            let attrs = cat.schema(t).sample_attrs(&mut rng);
            s.append(t, i * 777, JsonishCodec.encode(&attrs)).unwrap();
        }
        s
    }

    #[test]
    fn roundtrip_preserves_rows_exactly() {
        let a = populated();
        let b = from_bytes(&to_bytes(&a), StoreConfig::default()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.rows().iter().zip(b.rows()) {
            assert_eq!(x.event_type, y.event_type);
            assert_eq!(x.timestamp_ms, y.timestamp_ms);
            assert_eq!(x.payload, y.payload);
        }
        assert_eq!(a.storage_bytes(), b.storage_bytes());
    }

    #[test]
    fn loaded_store_answers_queries_identically() {
        use crate::applog::query::{retrieve, TimeWindow};
        let a = populated();
        let b = from_bytes(&to_bytes(&a), StoreConfig::default()).unwrap();
        let w = TimeWindow::last(80_000, 50_000);
        let ra = retrieve(&a, &[0, 2], w);
        let rb = retrieve(&b, &[0, 2], w);
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.timestamp_ms, y.timestamp_ms);
            assert_eq!(x.payload, y.payload);
        }
    }

    #[test]
    fn rejects_corruption() {
        let bytes = to_bytes(&populated());
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad, StoreConfig::default()).is_err());
        // Truncation.
        assert!(from_bytes(&bytes[..bytes.len() - 5], StoreConfig::default()).is_err());
        // Trailing garbage.
        let mut long = bytes.clone();
        long.push(0);
        assert!(from_bytes(&long, StoreConfig::default()).is_err());
        // Bad version.
        let mut v = bytes;
        v[4] = 9;
        assert!(from_bytes(&v, StoreConfig::default()).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("autofeature_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.aflg");
        let a = populated();
        save(&a, &path).unwrap();
        let b = load(&path, StoreConfig::default()).unwrap();
        assert_eq!(a.len(), b.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_store_roundtrips() {
        let s = AppLogStore::new(StoreConfig::default());
        let b = from_bytes(&to_bytes(&s), StoreConfig::default()).unwrap();
        assert!(b.is_empty());
    }
}
