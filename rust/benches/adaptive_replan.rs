//! Bench: adaptive re-lowering scenario suite — trigger trains that
//! force workload shifts (diurnal density swing, bursts, clock skew)
//! under pinned static lowerings vs the adaptive engine. Shows the
//! closed loop: adaptive tracks the best static arm per phase with ≥ 1
//! replan on the diurnal train, zero replans on stationary trains, and
//! bit-identical values throughout. `BENCH_QUICK=1` shrinks the phase
//! count; `BENCH_JSON_OUT=<path>` writes the suite as BENCH_9.json.

mod common;

use autofeature::harness::experiments;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok()
}

fn main() {
    common::run("adaptive_replan", || {
        let rows = experiments::ext_adaptive(common::scale())?;
        if let Ok(path) = std::env::var("BENCH_JSON_OUT") {
            let mut arms = String::new();
            for row in &rows {
                if !arms.is_empty() {
                    arms.push_str(",\n");
                }
                let col = |n: &str| row.get(n).unwrap_or(f64::NAN);
                arms.push_str(&format!(
                    "    {{\"scenario\": \"{}\", \"triggers\": {}, \
                     \"oneshot_ms\": {:.4}, \"cached_ms\": {:.4}, \
                     \"adaptive_ms\": {:.4}, \"best_static_ms\": {:.4}, \
                     \"replans\": {}, \"values_equal\": {}}}",
                    row.label,
                    col("triggers") as u64,
                    col("oneshot_ms"),
                    col("cached_ms"),
                    col("adaptive_ms"),
                    col("best_static_ms"),
                    col("replans") as u64,
                    col("values_equal") as u64,
                ));
            }
            let json = format!(
                "{{\n  \"pr\": 9,\n  \"bench\": \"adaptive_replan scenario suite\",\n  \
                 \"quick\": {},\n  \"arms\": [\n{}\n  ]\n}}\n",
                quick(),
                arms
            );
            std::fs::write(&path, json).unwrap();
            println!("wrote {path}");
        }
        Ok(())
    });
}
