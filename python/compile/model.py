"""Layer 2: the paper's generic on-device model (Fig. 13), in JAX.

Structure (verbatim from the paper's "Model Architecture" section):

  * Input layer — three feature categories:
      - ``stat``  [n_stat]        statistical user features + device features
      - ``seq``   [L, seq_dim]    sequential behavior features
      - ``cloud`` [n_cloud]       cloud-provided embeddings
  * Processing layer —
      - factorization-machine layer crossing the statistical/device
        features (Pallas kernel ``fm_kernel.fm_interaction``),
      - sequence encoder capturing temporal dynamics: a learned projection
        to keys/values plus masked attention pooling (Pallas kernel
        ``seq_attention.attention_pool``).
  * Output layer — two dense ReLU layers + sigmoid head.

Weights are generated deterministically from a per-service seed so the
Rust integration tests can compare the PJRT-executed artifact against
outputs dumped at AOT time. Batch size is fixed at 1: on-device inference
serves a single request at a time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.fm_kernel import fm_interaction
from .kernels.seq_attention import attention_pool


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Per-service model dimensions (Fig. 12a feature counts)."""

    name: str
    n_user: int  # user features (paper Fig. 12a)
    n_device: int = 8  # device features (volume, battery, ...)
    n_cloud: int = 16  # cloud embedding width
    seq_len: int = 32  # recent-behavior sequence length
    seq_dim: int = 8  # per-step behavior feature width
    emb_d: int = 16  # FM latent dimension
    hidden: int = 64  # dense layer width
    seed: int = 0

    @property
    def n_stat(self) -> int:
        return self.n_user + self.n_device


# The five services evaluated in the paper (§4.1), with their user-feature
# counts from Fig. 12a.
SERVICE_CONFIGS: Dict[str, ModelConfig] = {
    "cp": ModelConfig(name="cp", n_user=86, seed=101),
    "kp": ModelConfig(name="kp", n_user=53, seed=102),
    "sr": ModelConfig(name="sr", n_user=40, seed=103),
    "pr": ModelConfig(name="pr", n_user=103, seed=104),
    "vr": ModelConfig(name="vr", n_user=134, seed=105),
}


def init_params(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Deterministic parameter init from the config seed."""
    key = jax.random.PRNGKey(cfg.seed)
    ks = jax.random.split(key, 10)
    d = cfg.emb_d

    def glorot(k, shape):
        fan_in = shape[0]
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(
            jnp.float32(fan_in)
        )

    concat_dim = d + d + cfg.n_cloud + 1  # fm_vec, pooled, cloud, fm_linear
    return {
        "fm_w0": jnp.zeros((), jnp.float32),
        "fm_w": glorot(ks[0], (cfg.n_stat, 1)).reshape(cfg.n_stat),
        "fm_v": glorot(ks[1], (cfg.n_stat, d)),
        "seq_wk": glorot(ks[2], (cfg.seq_dim, d)),
        "seq_wv": glorot(ks[3], (cfg.seq_dim, d)),
        "seq_q": jax.random.normal(ks[4], (d,), jnp.float32),
        "mlp_w1": glorot(ks[5], (concat_dim, cfg.hidden)),
        "mlp_b1": jnp.zeros((cfg.hidden,), jnp.float32),
        "mlp_w2": glorot(ks[6], (cfg.hidden, cfg.hidden)),
        "mlp_b2": jnp.zeros((cfg.hidden,), jnp.float32),
        "mlp_w3": glorot(ks[7], (cfg.hidden, 1)),
        "mlp_b3": jnp.zeros((1,), jnp.float32),
    }


def forward(
    params: Dict[str, jnp.ndarray],
    stat: jnp.ndarray,  # [n_stat]
    seq: jnp.ndarray,  # [L, seq_dim]
    seq_mask: jnp.ndarray,  # [L]
    cloud: jnp.ndarray,  # [n_cloud]
    *,
    use_ref: bool = False,
) -> jnp.ndarray:
    """Single-request forward pass -> scalar prediction in (0, 1).

    ``use_ref=True`` swaps the Pallas kernels for the pure-jnp oracles;
    the pytest suite asserts both paths agree, which validates the kernels
    *inside* the full model graph, not just in isolation.
    """
    fm_fn = ref.fm_interaction_ref if use_ref else fm_interaction
    pool_fn = ref.attention_pool_ref if use_ref else attention_pool

    x = stat[None, :]  # [1, n_stat]
    fm_vec = fm_fn(x, params["fm_v"])  # [1, d]
    fm_linear = params["fm_w0"] + x @ params["fm_w"][:, None]  # [1, 1]

    k = seq @ params["seq_wk"]  # [L, d]
    v = seq @ params["seq_wv"]  # [L, d]
    pooled = pool_fn(
        params["seq_q"][None, :], k[None], v[None], seq_mask[None, :]
    )  # [1, d]

    h = jnp.concatenate([fm_vec, pooled, cloud[None, :], fm_linear], axis=-1)
    h = jax.nn.relu(h @ params["mlp_w1"] + params["mlp_b1"])
    h = jax.nn.relu(h @ params["mlp_w2"] + params["mlp_b2"])
    logit = h @ params["mlp_w3"] + params["mlp_b3"]
    return jax.nn.sigmoid(logit)[0, 0]


def make_inference_fn(cfg: ModelConfig, *, use_ref: bool = False):
    """Close over deterministic params -> fn(stat, seq, seq_mask, cloud).

    This is the function AOT-lowered to HLO: parameters are baked in as
    constants so the Rust runtime only feeds the four feature inputs.
    """
    params = init_params(cfg)

    def fn(stat, seq, seq_mask, cloud):
        return (forward(params, stat, seq, seq_mask, cloud, use_ref=use_ref),)

    return fn


def example_inputs(cfg: ModelConfig, seed: int = 7):
    """Deterministic sample inputs (used for AOT lowering + e2e checks)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    stat = jax.random.uniform(k1, (cfg.n_stat,), jnp.float32)
    seq = jax.random.normal(k2, (cfg.seq_len, cfg.seq_dim), jnp.float32)
    mask = jnp.ones((cfg.seq_len,), jnp.float32).at[cfg.seq_len // 2 :].set(0.0)
    cloud = jax.random.normal(k3, (cfg.n_cloud,), jnp.float32)
    return stat, seq, mask, cloud
