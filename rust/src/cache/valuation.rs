//! Caching-content valuation (paper §3.4).
//!
//! Per behavior type `E`:
//!   `U(E) = Num_Overlap(E) × Cost_Opt(E)` — computation saved on rows
//!   shared with the next execution;
//!   `C(E) = Num(E) × Size(E)`            — bytes to hold this
//!   execution's rows.
//!
//! The ratio `U/C` decomposes (Equation (a)) into a *dynamic* term
//! `Time_Overlap/Time_Range` (inference frequency, measured online) and
//! a *static* term `Cost_Opt/Size` (profiled once offline), so the
//! greedy policy ranks types in O(1) per type per execution.

use crate::applog::event::EventTypeId;

/// Statically profiled per-type constants (offline phase, Fig. 17a's
/// "profiling" bar).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StaticTerm {
    /// Retrieve+Decode cost per event, nanoseconds (the `Cost_Opt` the
    /// cache saves per overlapping row).
    pub cost_opt_ns_per_event: f64,
    /// Cached bytes per event (attr-union projection).
    pub bytes_per_event: f64,
}

impl StaticTerm {
    /// The static term of the decomposition: `Cost_Opt / Size`.
    pub fn ratio(&self) -> f64 {
        if self.bytes_per_event <= 0.0 {
            0.0
        } else {
            self.cost_opt_ns_per_event / self.bytes_per_event
        }
    }
}

/// A per-type caching candidate for one execution's knapsack instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Behavior type.
    pub event_type: EventTypeId,
    /// `U(E)`: expected saved nanoseconds.
    pub utility: f64,
    /// `C(E)`: bytes required to cache this execution's rows.
    pub cost_bytes: usize,
    /// `U/C` via term decomposition.
    pub ratio: f64,
}

/// Build a candidate from measured and profiled quantities.
///
/// * `num_rows` — rows of this type processed by the current execution
///   (measured),
/// * `measured_bytes` — actual bytes of their attr-union projections,
/// * `window_ms` — the type's retention window (max member window),
/// * `interval_ms` — measured/estimated inter-execution interval,
/// * `stat` — offline-profiled static term.
pub fn evaluate(
    event_type: EventTypeId,
    num_rows: usize,
    measured_bytes: usize,
    window_ms: i64,
    interval_ms: i64,
    stat: &StaticTerm,
) -> Candidate {
    // Term 1 (dynamic): Time_Overlap / Time_Range.
    let overlap_frac = if window_ms <= 0 {
        0.0
    } else {
        ((window_ms - interval_ms) as f64 / window_ms as f64).max(0.0)
    };
    // Num_Overlap = Num × overlap fraction (Equation (a) expresses Num as
    // Time_Range × Freq; the fraction cancels Freq).
    let num_overlap = num_rows as f64 * overlap_frac;
    let utility = num_overlap * stat.cost_opt_ns_per_event;
    let cost_bytes = measured_bytes;
    Candidate {
        event_type,
        utility,
        cost_bytes,
        ratio: overlap_frac * stat.ratio(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const STAT: StaticTerm = StaticTerm {
        cost_opt_ns_per_event: 2000.0,
        bytes_per_event: 100.0,
    };

    #[test]
    fn ratio_decomposition_matches_direct_computation() {
        let c = evaluate(0, 50, 5000, 60_000, 6_000, &STAT);
        // Direct: U/C = (50*0.9*2000) / (50*100) = 18; decomposition:
        // 0.9 * (2000/100) = 18.
        let direct = c.utility / c.cost_bytes as f64;
        assert!((c.ratio - direct).abs() < 1e-9, "{} vs {direct}", c.ratio);
    }

    #[test]
    fn no_overlap_when_interval_exceeds_window() {
        let c = evaluate(0, 50, 5000, 60_000, 120_000, &STAT);
        assert_eq!(c.utility, 0.0);
        assert_eq!(c.ratio, 0.0);
    }

    #[test]
    fn higher_frequency_increases_ratio() {
        let fast = evaluate(0, 50, 5000, 60_000, 1_000, &STAT);
        let slow = evaluate(0, 50, 5000, 60_000, 30_000, &STAT);
        assert!(fast.ratio > slow.ratio);
    }

    #[test]
    fn zero_size_is_guarded() {
        let stat = StaticTerm {
            cost_opt_ns_per_event: 100.0,
            bytes_per_event: 0.0,
        };
        assert_eq!(stat.ratio(), 0.0);
    }
}
