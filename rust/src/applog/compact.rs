//! Tail compaction: sealing the mutable append tail into immutable
//! columnar [`Segment`]s.
//!
//! The store appends into a small row-format tail; once the tail reaches
//! `StoreConfig::segment_rows` it is sealed. Sealing is purely a storage
//! re-layout — row content, order and seq_nos are untouched, which the
//! differential test sweep (`rust/tests/applog_differential.rs`) pins
//! bit-for-bit across compaction thresholds.

use super::arena::PayloadArena;
use super::event::BehaviorEvent;
use super::segment::{Segment, MAX_DICT_TYPES};

/// Seal `rows` (chronological, seq-increasing) into one or more
/// segments. Normally produces a single segment; splits early only when
/// a segment would exceed the one-byte type-dictionary capacity. With a
/// `shared` arena the segments intern their unique payloads host-wide
/// instead of holding private copies.
pub fn seal(rows: &[BehaviorEvent], shared: Option<&PayloadArena>) -> Vec<Segment> {
    let mut segments = Vec::new();
    let mut start = 0usize;
    while start < rows.len() {
        let mut distinct: Vec<u16> = Vec::new();
        let mut end = start;
        while end < rows.len() {
            let t = rows[end].event_type;
            if !distinct.contains(&t) {
                if distinct.len() == MAX_DICT_TYPES {
                    break;
                }
                distinct.push(t);
            }
            end += 1;
        }
        segments.push(Segment::build_in(&rows[start..end], shared));
        start = end;
    }
    segments
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(seq: u64, event_type: u16, ts: i64) -> BehaviorEvent {
        BehaviorEvent {
            seq_no: seq,
            event_type,
            timestamp_ms: ts,
            payload: vec![event_type as u8],
        }
    }

    #[test]
    fn seal_produces_one_segment_normally() {
        let rows: Vec<_> = (0..100).map(|i| row(i, (i % 5) as u16, i as i64)).collect();
        let segs = seal(&rows, None);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].len(), 100);
    }

    #[test]
    fn seal_splits_when_type_dictionary_would_overflow() {
        // 300 distinct types cannot share one segment's u8 code space.
        let rows: Vec<_> = (0..300).map(|i| row(i, i as u16, i as i64)).collect();
        let segs = seal(&rows, None);
        assert!(segs.len() >= 2);
        assert_eq!(segs.iter().map(|s| s.len()).sum::<usize>(), 300);
        assert_eq!(segs[0].len(), MAX_DICT_TYPES);
    }

    #[test]
    fn seal_empty_is_empty() {
        assert!(seal(&[], None).is_empty());
    }
}
