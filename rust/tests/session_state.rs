//! Hibernate/rehydrate integration suite: a session serialized to its
//! applog+state image at arbitrary trigger boundaries and rebuilt from
//! it must be indistinguishable from a twin that never slept —
//! bit-identical values, identical cache footprint, identical replay
//! counters — across all five services and the classic / cached /
//! incremental engine configurations. Damaged images must never
//! rehydrate: every single-byte corruption of either the packed image
//! or the bare state blob is rejected.

use autofeature::applog::codec::CodecKind;
use autofeature::applog::persist;
use autofeature::applog::store::{AppLogStore, StoreConfig};
use autofeature::engine::config::EngineConfig;
use autofeature::engine::online::Engine;
use autofeature::engine::Extractor;
use autofeature::features::compute::CompFunc;
use autofeature::features::spec::{FeatureId, FeatureSpec, TimeRange};
use autofeature::harness::eval_catalog;
use autofeature::util::rng::SimRng;
use autofeature::workload::services::{ServiceKind, ServiceSpec};
use autofeature::workload::traces::{log_events, TraceConfig, TraceGenerator};

/// Hibernate `engine` (with its `store`) into one image and rebuild
/// both from it.
fn round_trip(engine: &Engine, store: &AppLogStore, cfg: EngineConfig) -> (Engine, AppLogStore) {
    let image = persist::to_bytes_with_session(store, &engine.export_state()).unwrap();
    let (new_store, state) =
        persist::from_bytes_with_session(&image, StoreConfig::default()).unwrap();
    let mut revived = Engine::from_shared(engine.shared_plan(), cfg);
    revived.import_state(&state.expect("image carries a session block")).unwrap();
    (revived, new_store)
}

#[test]
fn hibernation_is_invisible_across_services_and_configs() {
    let catalog = eval_catalog();
    let mut rng = SimRng::seed_from_u64(0x5E55_10);
    for kind in ServiceKind::ALL {
        let svc = ServiceSpec::build(kind, &catalog);
        let trace = TraceGenerator::new(&catalog).generate(&TraceConfig {
            duration_ms: 14 * 60_000,
            seed: 0xFEED ^ kind.id().len() as u64,
            ..TraceConfig::default()
        });
        for (label, cfg) in [
            ("classic", EngineConfig::fusion_only()),
            ("cached", EngineConfig::autofeature()),
            ("incremental", EngineConfig::incremental()),
        ] {
            let ctx = |extra: &dyn std::fmt::Display| {
                format!("{}/{label}: {extra}", kind.id())
            };
            let mut twin = Engine::new(svc.features.clone(), &catalog, cfg).unwrap();
            let mut hib = Engine::from_shared(twin.shared_plan(), cfg);
            let mut store = AppLogStore::new(StoreConfig::default());
            let mut hib_store = AppLogStore::new(StoreConfig::default());
            let codec = CodecKind::Jsonish.build();
            let mut next_event = 0usize;
            let mut hib_next_event = 0usize;
            let mut hibernated = 0usize;
            // Triggers every 30 s over the back half of the trace; the
            // hibernating session sleeps at random boundaries.
            for step in 0..14i64 {
                let now = 7 * 60_000 + step * 30_000;
                let upto = trace.partition_point(|e| e.timestamp_ms < now);
                if upto > next_event {
                    log_events(&mut store, codec.as_ref(), &trace[next_event..upto]).unwrap();
                    next_event = upto;
                }
                if upto > hib_next_event {
                    log_events(
                        &mut hib_store,
                        codec.as_ref(),
                        &trace[hib_next_event..upto],
                    )
                    .unwrap();
                    hib_next_event = upto;
                }
                let a = twin.extract(&store, now).unwrap();
                let b = hib.extract(&hib_store, now).unwrap();
                assert_eq!(a.values, b.values, "{}", ctx(&format!("step {step}")));
                assert_eq!(
                    a.cache_bytes,
                    b.cache_bytes,
                    "{}",
                    ctx(&format!("step {step} cache"))
                );
                assert_eq!(
                    a.breakdown.rows_replayed,
                    b.breakdown.rows_replayed,
                    "{}",
                    ctx(&format!("step {step} replay"))
                );
                if rng.bool_p(0.4) {
                    let (revived, revived_store) = round_trip(&hib, &hib_store, cfg);
                    hib = revived;
                    hib_store = revived_store;
                    hibernated += 1;
                }
            }
            assert!(hibernated > 0, "{}", ctx(&"rng never hibernated"));
        }
    }
}

#[test]
fn clean_rehydrate_replays_zero_rows() {
    // Count/Sum windows never exhaust their delta state, so a warm
    // incremental session replays zero rows per trigger — and a
    // rehydrated one must too (watermark + IncBank continuity).
    let catalog = eval_catalog();
    let specs: Vec<FeatureSpec> = [CompFunc::Count, CompFunc::Sum, CompFunc::Mean]
        .iter()
        .enumerate()
        .map(|(i, comp)| {
            FeatureSpec {
                id: FeatureId(i as u32),
                name: format!("steady_{i}"),
                event_types: vec![2],
                window: TimeRange::mins(5),
                attrs: vec![0],
                comp: *comp,
            }
            .normalized()
        })
        .collect();
    let cfg = EngineConfig::incremental();
    let trace = TraceGenerator::new(&catalog).generate(&TraceConfig {
        duration_ms: 12 * 60_000,
        seed: 0xC0FFEE,
        ..TraceConfig::default()
    });
    let codec = CodecKind::Jsonish.build();
    let mut store = AppLogStore::new(StoreConfig::default());
    let mut engine = Engine::new(specs, &catalog, cfg).unwrap();
    let mut next_event = 0usize;
    let mut warm_replay = None;
    for step in 0..8i64 {
        let now = 6 * 60_000 + step * 30_000;
        let upto = trace.partition_point(|e| e.timestamp_ms < now);
        if upto > next_event {
            log_events(&mut store, codec.as_ref(), &trace[next_event..upto]).unwrap();
            next_event = upto;
        }
        let r = engine.extract(&store, now).unwrap();
        if step > 0 {
            assert_eq!(r.breakdown.rows_replayed, 0, "warm step {step} replayed");
            warm_replay = Some(r.breakdown.rows_replayed);
        }
    }
    assert_eq!(warm_replay, Some(0));

    let (mut revived, revived_store) = round_trip(&engine, &store, cfg);
    // Same trigger cadence, no new events: the rehydrated engine's very
    // next extraction is pure delta work.
    let now = 6 * 60_000 + 8 * 30_000;
    let r = revived.extract(&revived_store, now).unwrap();
    assert_eq!(
        r.breakdown.rows_replayed, 0,
        "rehydration forced a replay ({} rows)",
        r.breakdown.rows_replayed
    );
    let want = engine.extract(&store, now).unwrap();
    assert_eq!(want.values, r.values);
}

#[test]
fn every_single_byte_corruption_is_rejected() {
    // A deliberately small session: short trace, few features, so the
    // full-image sweep stays cheap while still covering the header, the
    // applog rows, the session block and both CRCs.
    let catalog = eval_catalog();
    let specs: Vec<FeatureSpec> = vec![
        FeatureSpec {
            id: FeatureId(0),
            name: "probe_count".into(),
            event_types: vec![1],
            window: TimeRange::mins(3),
            attrs: vec![0],
            comp: CompFunc::Count,
        }
        .normalized(),
        FeatureSpec {
            id: FeatureId(1),
            name: "probe_latest".into(),
            event_types: vec![1, 3],
            window: TimeRange::mins(2),
            attrs: vec![0, 1],
            comp: CompFunc::Latest,
        }
        .normalized(),
    ];
    let cfg = EngineConfig::incremental();
    let trace = TraceGenerator::new(&catalog).generate(&TraceConfig {
        duration_ms: 3 * 60_000,
        seed: 99,
        ..TraceConfig::default()
    });
    let codec = CodecKind::Jsonish.build();
    let mut store = AppLogStore::new(StoreConfig::default());
    let mut engine = Engine::new(specs, &catalog, cfg).unwrap();
    let mut next_event = 0usize;
    for now in [2 * 60_000i64, 2 * 60_000 + 30_000] {
        let upto = trace.partition_point(|e| e.timestamp_ms < now);
        log_events(&mut store, codec.as_ref(), &trace[next_event..upto]).unwrap();
        next_event = upto;
        engine.extract(&store, now).unwrap();
    }

    // The packed image: any single corrupt byte must fail the load (the
    // snapshot CRC covers the embedded session block too).
    let image = persist::to_bytes_with_session(&store, &engine.export_state()).unwrap();
    assert!(persist::from_bytes_with_session(&image, StoreConfig::default()).is_ok());
    for i in 0..image.len() {
        let mut bad = image.clone();
        bad[i] ^= 0xA5;
        assert!(
            persist::from_bytes_with_session(&bad, StoreConfig::default()).is_err(),
            "byte {i}/{} corruption of the image went unnoticed",
            image.len()
        );
    }

    // The bare state blob: any single corrupt byte must fail the import
    // and leave the target engine intact.
    let state = engine.export_state();
    for i in 0..state.len() {
        let mut bad = state.clone();
        bad[i] ^= 0xA5;
        let mut target = Engine::from_shared(engine.shared_plan(), cfg);
        assert!(
            target.import_state(&bad).is_err(),
            "byte {i}/{} corruption of the state blob went unnoticed",
            state.len()
        );
        target.import_state(&state).unwrap();
    }
}
