//! Intra-feature chain partition (paper §3.3).
//!
//! The root cause of overgeneralized fused conditions is the
//! orthogonality of the `Retrieve` node's two conditions
//! (`event_names` × `time_range`): fusing `Retrieve(A∪B, max(w1,w2))`
//! pulls rows neither feature wants. Splitting every feature chain into
//! one sub-chain per `event_name` (each keeping the original
//! `time_range`) exposes finer-grained fusion that never widens the
//! event-type scope.

use crate::applog::event::{AttrId, EventTypeId};
use crate::features::compute::CompFunc;
use crate::features::spec::{FeatureSpec, TimeRange};

/// One per-event-type sub-chain of a feature's operation chain.
#[derive(Debug, Clone, PartialEq)]
pub struct SubChain {
    /// Index of the owning feature in the model's spec list.
    pub feature_idx: usize,
    /// The single `event_name` condition of this sub-chain.
    pub event_type: EventTypeId,
    /// The original `time_range` condition (not widened).
    pub window: TimeRange,
    /// The feature's `attr_names` condition.
    pub attrs: Vec<AttrId>,
    /// The feature's `comp_func` condition.
    pub comp: CompFunc,
}

/// Partition every feature chain into per-event-type sub-chains.
pub fn partition(features: &[FeatureSpec]) -> Vec<SubChain> {
    let mut out = Vec::new();
    for (idx, f) in features.iter().enumerate() {
        for &t in &f.event_types {
            out.push(SubChain {
                feature_idx: idx,
                event_type: t,
                window: f.window,
                attrs: f.attrs.clone(),
                comp: f.comp,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::spec::FeatureId;

    fn spec(id: u32, types: Vec<u16>) -> FeatureSpec {
        FeatureSpec {
            id: FeatureId(id),
            name: format!("f{id}"),
            event_types: types,
            window: TimeRange::mins(id as i64 + 1),
            attrs: vec![0, 2],
            comp: CompFunc::Sum,
        }
        .normalized()
    }

    #[test]
    fn one_subchain_per_event_type() {
        let specs = vec![spec(0, vec![1, 4, 7]), spec(1, vec![4])];
        let subs = partition(&specs);
        assert_eq!(subs.len(), 4);
        assert_eq!(
            subs.iter().filter(|s| s.event_type == 4).count(),
            2,
            "both features contribute a type-4 sub-chain"
        );
    }

    #[test]
    fn subchains_keep_original_window_and_attrs() {
        let specs = vec![spec(2, vec![3, 5])];
        for s in partition(&specs) {
            assert_eq!(s.window, TimeRange::mins(3));
            assert_eq!(s.attrs, vec![0, 2]);
            assert_eq!(s.feature_idx, 0);
        }
    }
}
