//! # AutoFeature
//!
//! A reproduction of *"Optimizing Feature Extraction for On-device Model
//! Inference with User Behavior Sequences"* (SenSys '26): an on-device
//! feature-extraction engine that accelerates end-to-end ML model
//! execution by eliminating redundant `Retrieve`/`Decode`/`Filter`/
//! `Compute` operations across different input features (FE-graph fusion,
//! §3.3) and across consecutive model executions (knapsack-style caching
//! of decoded attributes, §3.4).
//!
//! ## Layer map
//!
//! * [`applog`] — the on-device app-log substrate (SQLite-analogue):
//!   chronological behavior-event rows with a compressed
//!   behavior-specific-attribute column.
//! * [`features`] — feature condition tuples `<event_names, time_range,
//!   attr_names, comp_func>` and computation functions.
//! * [`fegraph`] — the FE-graph abstraction and direct (unoptimized)
//!   execution; redundancy identification.
//! * [`optimizer`] — intra-feature chain partition, inter-feature fusion
//!   with branch postposition, hierarchical filtering.
//! * [`cache`] — event evaluator: utility/cost valuation, greedy knapsack
//!   policy (plus DP/random baselines), memory-budgeted cache store.
//! * [`engine`] — offline optimization + online execution phases.
//! * [`baseline`] — industry-standard naive extraction and the two
//!   cloud-side systems (*Decoded Log*, *Feature Store*) of Table 1.
//! * [`workload`] — behavior catalog, seeded user-trace generator and the
//!   five evaluated services (CP/KP/SR/PR/VR).
//! * [`runtime`] — model inference backends: the PJRT CPU client over
//!   AOT-compiled JAX models (`pjrt` feature) and a pure-Rust surrogate.
//! * [`coordinator`] — the service loop wiring traces → extraction →
//!   model inference, plus the sharded multi-user
//!   [`coordinator::pool::SessionPool`] serving many sessions from one
//!   shared compiled plan under a global cache-budget arbiter.
//! * [`harness`] — experiment drivers regenerating every paper table and
//!   figure (used by `benches/` and `examples/`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use autofeature::prelude::*;
//!
//! // Build a small behavior catalog and log some events.
//! let catalog = Catalog::generate(&CatalogConfig::small(), 1);
//! let mut store = AppLogStore::new(StoreConfig::default());
//! // ... append events, define features, run the engine (see examples/).
//! ```
#![warn(missing_docs)]

pub mod applog;
pub mod baseline;
pub mod cache;
pub mod coordinator;
pub mod engine;
pub mod features;
pub mod fegraph;
pub mod harness;
pub mod optimizer;
pub mod runtime;
pub mod util;
pub mod workload;

/// Convenient re-exports of the most common public types.
pub mod prelude {
    pub use crate::applog::{
        codec::{AttrCodec, BinaryCodec, CodecKind, JsonishCodec},
        event::{AttrId, AttrValue, BehaviorEvent, EventTypeId, TimestampMs},
        schema::{AttrKind, AttrSchema, BehaviorSchema, Catalog, CatalogConfig},
        store::{AppLogStore, StoreConfig},
    };
    pub use crate::baseline::naive::NaiveExtractor;
    pub use crate::cache::arbiter::CacheArbiter;
    pub use crate::cache::policy::PolicyKind;
    pub use crate::coordinator::pool::{PoolConfig, PoolReport, SessionConfig, SessionPool};
    pub use crate::coordinator::sched::{FleetScheduler, SchedConfig, SchedReport};
    pub use crate::engine::{
        config::EngineConfig,
        online::{Engine, ExtractionResult},
    };
    pub use crate::features::{
        compute::CompFunc,
        spec::{FeatureId, FeatureSpec, TimeRange},
        value::FeatureValue,
    };
    pub use crate::fegraph::graph::FeGraph;
    pub use crate::workload::{
        services::{ServiceKind, ServiceSpec},
        traces::{Period, TraceConfig, TraceGenerator},
    };
}
