//! Bench: Fig. 19 — component-wise analysis on the VR service:
//! (a) per-operation latency before/after inter-feature fusion,
//! (b) greedy vs random cache policy under a budget sweep.

mod common;

use autofeature::harness::experiments;

fn main() {
    common::run("fig19_component", || {
        experiments::fig19a_component(common::scale())?;
        experiments::fig19b_cache_policy(common::scale())?;
        Ok(())
    });
}
