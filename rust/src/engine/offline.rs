//! The offline optimization phase (paper §3.1, Fig. 7 ①②③).
//!
//! Runs once when a model (or an updated configuration) is deployed:
//! 1. **Graph generator** — formulate the FE-graph from the feature
//!    conditions,
//! 2. **Graph optimizer** — intra-feature partition + inter-feature
//!    fusion into the optimized plan,
//! 3. **Output evaluator** — profile per-type costs/sizes for the cache
//!    valuation's static terms.
//!
//! The paper measures this phase at millisecond scale (Fig. 17a);
//! [`OfflineStats`] records the same breakdown.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::applog::event::{AttrId, EventTypeId};
use crate::applog::schema::Catalog;
use crate::features::spec::FeatureSpec;
use crate::fegraph::graph::FeGraph;
use crate::optimizer::fusion::fuse;
use crate::optimizer::lower::{lower, ExecPlan, LowerConfig};
use crate::optimizer::plan::OptimizedPlan;

use super::config::EngineConfig;
use super::profiler::{profile, ProfileTable};

/// Wall-clock breakdown of the offline phase (Fig. 17a).
#[derive(Debug, Clone, Copy, Default)]
pub struct OfflineStats {
    /// FE-graph construction time.
    pub graph_build_ns: u64,
    /// Partition + fusion time.
    pub optimize_ns: u64,
    /// Per-type profiling time (the dominant bar in Fig. 17a).
    pub profile_ns: u64,
}

impl OfflineStats {
    /// Total offline time.
    pub fn total_ns(&self) -> u64 {
        self.graph_build_ns + self.optimize_ns + self.profile_ns
    }
}

/// Everything the online phase needs, produced once offline.
#[derive(Debug)]
pub struct CompiledEngine {
    /// The unoptimized FE-graph (kept for reporting/inspection).
    pub graph: FeGraph,
    /// The optimized execution plan (lane/group geometry).
    pub plan: OptimizedPlan,
    /// The lowered operator-pipeline IR the executor runs: strategy,
    /// staged operators, per-operator fingerprints.
    pub exec: ExecPlan,
    /// Profiled static valuation terms.
    pub profile: ProfileTable,
    /// Per-type retention horizon: max member window (cache prune
    /// cutoff and missing-interval bound).
    pub type_windows: HashMap<EventTypeId, i64>,
    /// Per-type attr unions (cache row projection).
    pub attr_unions: HashMap<EventTypeId, Vec<AttrId>>,
    /// Offline phase timing.
    pub stats: OfflineStats,
}

impl CompiledEngine {
    /// The plan's longest feature window, ms (≥ 1). The adaptive cost
    /// model's gap/span fresh-volume counterfactual normalizes trigger
    /// gaps against this constant.
    pub fn span_ms(&self) -> i64 {
        self.type_windows.values().copied().max().unwrap_or(0).max(1)
    }
}

/// The [`EngineConfig`] → [`LowerConfig`] projection used at compile
/// time. The adaptive engine replicates it as the baseline of its
/// per-session overlay, so the cost model's "current configuration"
/// starts exactly where `compile` left the shared plan.
pub(crate) fn lower_config(cfg: &EngineConfig) -> LowerConfig {
    LowerConfig {
        enable_cache: cfg.enable_cache,
        incremental_compute: cfg.incremental_compute,
        hierarchical_filter: cfg.hierarchical_filter,
        projected_decode: true,
        batch_exec: !cfg.row_walk_exec,
    }
}

/// Compile a feature set for online execution.
pub fn compile(
    features: Vec<FeatureSpec>,
    catalog: &Catalog,
    cfg: &EngineConfig,
) -> Result<CompiledEngine> {
    let mut stats = OfflineStats::default();

    // ① Graph generator.
    let t0 = Instant::now();
    let graph = FeGraph::from_specs(features);
    stats.graph_build_ns = t0.elapsed().as_nanos() as u64;

    // ② Graph optimizer (partition + fusion), then lowering to the
    // ExecPlan IR — the execution strategy is decided here, once, not
    // branch-by-branch inside the online engine.
    let t0 = Instant::now();
    let plan = fuse(&graph.features, cfg.enable_fusion);
    let exec = lower(&plan, &lower_config(cfg));
    let mut type_windows: HashMap<EventTypeId, i64> = HashMap::new();
    let mut attr_unions: HashMap<EventTypeId, Vec<AttrId>> = HashMap::new();
    for lane in &plan.lanes {
        let w = type_windows.entry(lane.event_type).or_insert(0);
        *w = (*w).max(lane.max_window.duration_ms);
        let u = attr_unions.entry(lane.event_type).or_default();
        u.extend(lane.attr_union.iter().copied());
    }
    for u in attr_unions.values_mut() {
        u.sort_unstable();
        u.dedup();
    }
    stats.optimize_ns = t0.elapsed().as_nanos() as u64;

    // ③ Output evaluator: profile static terms.
    let codec = cfg.codec.build();
    let prof = profile(catalog, codec.as_ref(), &attr_unions)?;
    stats.profile_ns = prof.profile_time_ns;

    Ok(CompiledEngine {
        graph,
        plan,
        exec,
        profile: prof,
        type_windows,
        attr_unions,
        stats,
    })
}

impl CompiledEngine {
    /// Render the lowered plan (`autofeature explain`, golden plan
    /// snapshots). Delegates to [`ExecPlan::explain`].
    pub fn explain(&self) -> String {
        self.exec.explain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::applog::schema::CatalogConfig;
    use crate::features::catalog::{generate_feature_set, FeatureSetConfig, MEANINGFUL_WINDOWS};

    fn setup(enable_fusion: bool) -> CompiledEngine {
        let cat = Catalog::generate(&CatalogConfig::paper(), 42);
        let specs = generate_feature_set(
            &cat,
            &FeatureSetConfig {
                num_features: 40,
                num_types: 10,
                identical_share: 0.6,
                windows: MEANINGFUL_WINDOWS.to_vec(),
                multi_type_prob: 0.3,
                seed: 5,
            },
        );
        let cfg = EngineConfig {
            enable_fusion,
            ..EngineConfig::autofeature()
        };
        compile(specs, &cat, &cfg).unwrap()
    }

    #[test]
    fn compile_profiles_every_plan_type() {
        let c = setup(true);
        for lane in &c.plan.lanes {
            assert!(c.profile.contains(lane.event_type));
            assert!(c.type_windows.contains_key(&lane.event_type));
        }
    }

    #[test]
    fn fused_plan_has_fewer_lanes() {
        let fused = setup(true);
        let unfused = setup(false);
        assert!(fused.plan.num_retrieves() < unfused.plan.num_retrieves());
    }

    #[test]
    fn offline_phase_is_fast_and_timed() {
        let c = setup(true);
        assert!(c.stats.graph_build_ns > 0);
        assert!(c.stats.profile_ns > 0);
        // Paper: millisecond-scale offline cost. Allow generous slack on
        // CI boxes but catch pathological blowups.
        assert!(c.stats.total_ns() < 500_000_000, "{}", c.stats.total_ns());
    }

    #[test]
    fn compile_lowers_the_exec_plan() {
        let c = setup(true);
        assert_eq!(
            c.exec.strategy,
            crate::optimizer::lower::Strategy::CachedRewalk
        );
        assert_eq!(c.exec.pipelines.len(), c.plan.lanes.len());
        assert!(
            c.explain().starts_with("ExecPlan strategy=cached-rewalk"),
            "{}",
            c.explain()
        );
    }

    #[test]
    fn attr_unions_cover_member_attrs() {
        let c = setup(true);
        for lane in &c.plan.lanes {
            let u = &c.attr_unions[&lane.event_type];
            for g in &lane.groups {
                for m in &g.members {
                    for a in &m.attrs {
                        assert!(u.binary_search(a).is_ok());
                    }
                }
            }
        }
    }
}
