//! Global cache-budget arbiter for multi-session deployments.
//!
//! One host process serving many user sessions (the
//! [`crate::coordinator::pool::SessionPool`]) must keep the *sum* of all
//! per-session cache footprints under a device- or host-wide cap. The
//! arbiter divides the cap evenly across live sessions and redistributes
//! it on session churn: when a session completes, the survivors pick up
//! the freed share at their next extraction via the engine's existing
//! dynamic-budget hook ([`crate::engine::online::Engine::set_cache_budget`],
//! which evicts lowest-priority lanes when shrinking).
//!
//! Invariant: every live session's applied budget is `cap / live` as of
//! some instant at which `live` was no larger than it is now (live only
//! shrinks), so the sum of applied budgets — and therefore the total
//! cached bytes — never exceeds `cap`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Divides a global cache cap across live sessions and tracks the
/// fleet-wide cache footprint. All methods are `&self`: one arbiter is
/// shared by every pool worker thread.
#[derive(Debug)]
pub struct CacheArbiter {
    cap_bytes: usize,
    live: AtomicUsize,
    /// Last reported cache bytes per session slot (each slot is written
    /// only by the worker thread that owns the session).
    usage: Vec<AtomicUsize>,
    /// Running sum of all slots, maintained by delta so reporting stays
    /// O(1) per extraction regardless of fleet size.
    total: AtomicUsize,
    /// Peak of `total` ever observed.
    peak_total: AtomicUsize,
}

impl CacheArbiter {
    /// Create an arbiter for `num_sessions` initially-live sessions
    /// under a global `cap_bytes`. Session slots are `0..num_sessions`.
    pub fn new(cap_bytes: usize, num_sessions: usize) -> CacheArbiter {
        CacheArbiter {
            cap_bytes,
            live: AtomicUsize::new(num_sessions),
            usage: (0..num_sessions).map(|_| AtomicUsize::new(0)).collect(),
            total: AtomicUsize::new(0),
            peak_total: AtomicUsize::new(0),
        }
    }

    /// The global cap.
    pub fn cap_bytes(&self) -> usize {
        self.cap_bytes
    }

    /// Sessions still running.
    pub fn live_sessions(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }

    /// The per-session budget at this instant: an even split of the cap
    /// across live sessions. Applied by each session right before its
    /// next extraction, so budget growth after churn takes effect
    /// lazily (and safely: stale budgets are only ever smaller).
    pub fn session_budget(&self) -> usize {
        self.cap_bytes / self.live_sessions().max(1)
    }

    /// Record one session's cache footprint after an extraction and
    /// update the fleet-wide peak. O(1): only the delta against the
    /// slot's previous report touches the shared total.
    pub fn report_usage(&self, slot: usize, cache_bytes: usize) {
        let prev = self.usage[slot].swap(cache_bytes, Ordering::AcqRel);
        let total = if cache_bytes >= prev {
            let d = cache_bytes - prev;
            self.total.fetch_add(d, Ordering::AcqRel) + d
        } else {
            let d = prev - cache_bytes;
            self.total.fetch_sub(d, Ordering::AcqRel) - d
        };
        self.peak_total.fetch_max(total, Ordering::AcqRel);
    }

    /// Mark a session finished: its cache is dropped with its engine and
    /// its share of the cap is redistributed to the survivors.
    pub fn complete(&self, slot: usize) {
        let prev = self.usage[slot].swap(0, Ordering::AcqRel);
        self.total.fetch_sub(prev, Ordering::AcqRel);
        self.live.fetch_sub(1, Ordering::AcqRel);
    }

    /// Current summed cache bytes across live sessions.
    pub fn total_bytes(&self) -> usize {
        self.total.load(Ordering::Acquire)
    }

    /// Peak summed cache bytes observed over the run.
    pub fn peak_total_bytes(&self) -> usize {
        self.peak_total.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_is_even_split_of_cap() {
        let a = CacheArbiter::new(64 * 1024, 8);
        assert_eq!(a.session_budget(), 8 * 1024);
        assert_eq!(a.live_sessions(), 8);
    }

    #[test]
    fn churn_redistributes_budget() {
        let a = CacheArbiter::new(60_000, 3);
        assert_eq!(a.session_budget(), 20_000);
        a.complete(0);
        assert_eq!(a.live_sessions(), 2);
        assert_eq!(a.session_budget(), 30_000);
        a.complete(1);
        a.complete(2);
        // Guard: never divide by zero once everything finished.
        assert_eq!(a.session_budget(), 60_000);
    }

    #[test]
    fn usage_tracking_and_peak() {
        let a = CacheArbiter::new(100, 2);
        a.report_usage(0, 30);
        a.report_usage(1, 50);
        assert_eq!(a.total_bytes(), 80);
        a.report_usage(1, 10);
        assert_eq!(a.total_bytes(), 40);
        assert_eq!(a.peak_total_bytes(), 80);
        a.complete(0);
        assert_eq!(a.total_bytes(), 10);
    }

    #[test]
    fn budgets_never_oversubscribe_cap() {
        // Simulated churn: sessions always apply the *current* split;
        // the sum of applied budgets stays under the cap throughout.
        let cap = 90_000;
        let a = CacheArbiter::new(cap, 5);
        let mut applied = vec![0usize; 5];
        for finished in 0..5usize {
            for (slot, b) in applied.iter_mut().enumerate().skip(finished) {
                *b = a.session_budget();
                a.report_usage(slot, *b); // worst case: budget fully used
            }
            assert!(
                applied[finished..].iter().sum::<usize>() <= cap,
                "oversubscribed after {finished} completions"
            );
            a.complete(finished);
        }
        assert!(a.peak_total_bytes() <= cap);
    }
}
