"""Pallas kernel: masked single-head attention pooling (Layer 1).

The sequence encoder of the paper's Fig. 13 model pools the recent
behavior-sequence features into one vector per request:

    out = softmax(q . K^T / sqrt(d), masked) @ V        # [B, d]

TPU mapping: one grid step per batch row; K/V for that row live in VMEM
([L, d] tiles), the logit/softmax reduction is VPU work and the weighted
sum is a [1, L] x [L, d] MXU matmul. L and d are padded to multiples of 8
so tiles stay aligned. Runs under ``interpret=True`` on this CPU image;
validated against ``ref.attention_pool_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, true_d: int):
    q = q_ref[...]  # [1, d_padded]
    k = k_ref[0]  # block is [1, L, d] -> [L, d]
    v = v_ref[0]  # [L, d]
    mask = mask_ref[...]  # [1, L]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [1, L]
    # Scale by the *unpadded* head dim: padding lanes are zero and add
    # nothing to the dot product, but they must not change the scale.
    logits = logits / (true_d**0.5)
    logits = jnp.where(mask > 0, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * (mask > 0)
    z = jnp.sum(e, axis=-1, keepdims=True)
    w = e / jnp.maximum(z, 1e-30)  # [1, L]
    o_ref[...] = jnp.dot(w, v, preferred_element_type=jnp.float32)


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    rem = x.shape[axis] % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@jax.jit
def attention_pool(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Masked attention pooling via a Pallas kernel.

    Args:
      q: ``[B, d]`` queries.
      k: ``[B, L, d]`` keys.
      v: ``[B, L, d]`` values.
      mask: ``[B, L]`` validity mask (1 = valid, 0 = padding). Padding
        introduced internally is masked out, so results match the ref
        oracle exactly for any L/d.

    Returns:
      ``[B, d]`` pooled vectors.
    """
    b, l, d = k.shape
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    mask = mask.astype(jnp.float32)

    # Align L and d to 8-lane tiles; padded keys are masked out.
    kp = _pad_axis(_pad_axis(k, 1, 8), 2, 8)
    vp = _pad_axis(_pad_axis(v, 1, 8), 2, 8)
    qp = _pad_axis(q, 1, 8)
    mp = _pad_axis(mask, 1, 8)
    lp, dp = kp.shape[1], kp.shape[2]

    kernel = functools.partial(_attn_kernel, true_d=d)
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, lp, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lp, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, dp), jnp.float32),
        interpret=True,  # CPU image: Mosaic lowering is TPU-only
    )(qp, kp, vp, mp)
    return out[:, :d]
